"""deepseek-67b: dense llama-arch, 95L d=8192 64H GQA kv=8 d_ff=22016.

[arXiv:2401.02954; hf]
"""
import dataclasses
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-67b",
    family="dense",
    n_layers=95,
    d_model=8192,
    n_heads=64,
    n_kv_heads=8,
    d_ff=22016,
    vocab=102400,
    block_pattern=(("attn", "mlp"),),
    dtype="bfloat16",
    source="arXiv:2401.02954",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=64, n_heads=8, n_kv_heads=2, d_ff=128,
        vocab=256, dtype="float32",
    )
