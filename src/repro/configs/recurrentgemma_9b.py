"""recurrentgemma-9b: 38 blocks d=4096 16H(kv=1) d_ff=12288 vocab=256k.

RG-LRU recurrent blocks + local attention, 2:1 pattern; sub-quadratic
(runs long_500k). [arXiv:2402.19427; unverified]
"""
import dataclasses
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="recurrentgemma-9b",
    family="hybrid",
    n_layers=38,
    d_model=4096,
    n_heads=16,
    n_kv_heads=1,
    d_ff=12288,
    vocab=256000,
    block_pattern=(
        ("rglru", "mlp"), ("rglru", "mlp"), ("attn_local", "mlp"),
    ),
    extras=(("window", 2048), ("lru_width", 4096)),
    dtype="bfloat16",
    sub_quadratic=True,
    source="arXiv:2402.19427",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=3, d_model=48, n_heads=4, n_kv_heads=1, d_ff=96,
        vocab=256, extras=(("window", 8), ("lru_width", 48)), dtype="float32",
    )
