"""whisper-tiny: 4L enc + 4L dec, d=384, 6H, d_ff=1536, vocab 51865.

Encoder-decoder with conv audio frontend STUB (input_specs provides
precomputed frame embeddings at d_model). [arXiv:2212.04356; unverified]
Pipeline layout: concat-carry (enc_seq + dec_seq), uniform enc+dec joint
blocks with per-stage role masks (DESIGN.md Sec. 5).
"""
import dataclasses
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="whisper-tiny",
    family="encdec",
    n_layers=4,  # joint enc+dec blocks (4 enc || 4 dec, concat-carry)
    d_model=384,
    n_heads=6,
    n_kv_heads=6,
    d_ff=1536,
    vocab=51865,
    block_pattern=(("encdec",),),
    extras=(("s_enc", 1500), ("frontend_dim", 384)),
    dtype="bfloat16",
    source="arXiv:2212.04356",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=32, n_heads=2, n_kv_heads=2, d_ff=64,
        vocab=128, extras=(("s_enc", 8), ("frontend_dim", 32)), dtype="float32",
    )
