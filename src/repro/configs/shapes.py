"""Input-shape cells for the dry run: 4 shapes x 10 architectures.

  train_4k     seq 4096,    global_batch 256  -> train_step
  prefill_32k  seq 32768,   global_batch 32   -> serve prefill
  decode_32k   seq 32768 KV, global_batch 128 -> serve decode (1 new token)
  long_500k    seq 524288 KV, global_batch 1  -> long-context decode
                (sub-quadratic archs only; skips recorded per arch)
"""

from __future__ import annotations

import dataclasses
from typing import List, Optional, Tuple

from . import ARCH_IDS, get_config

__all__ = ["ShapeCell", "SHAPES", "cells_for", "all_cells"]


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    shape_id: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


SHAPES = {
    "train_4k": ShapeCell("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeCell("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeCell("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeCell("long_500k", 524288, 1, "decode"),
}


def cells_for(arch_id: str) -> List[Tuple[str, ShapeCell, Optional[str]]]:
    """(shape_id, cell, skip_reason) for one arch.  40 cells total; skipped
    cells are still listed with the reason recorded (EXPERIMENTS.md)."""
    cfg = get_config(arch_id)
    out = []
    for sid, cell in SHAPES.items():
        skip = None
        if sid == "long_500k" and not cfg.sub_quadratic:
            skip = "full-attention arch: 500k decode is quadratic (DESIGN.md Sec. 5)"
        if cell.kind == "decode" and not cfg.has_decoder:
            skip = "encoder-only arch has no decode step"
        out.append((sid, cell, skip))
    return out


def all_cells():
    for a in ARCH_IDS:
        for sid, cell, skip in cells_for(a):
            yield a, sid, cell, skip
