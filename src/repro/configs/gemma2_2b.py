"""gemma2-2b: 26L d=2304 8H GQA kv=4 d_ff=9216 vocab=256k.

Local(4096)/global alternating attention + logit softcap.
long_500k SKIPPED: global layers are full attention (quadratic).
[arXiv:2408.00118; hf]
"""
import dataclasses
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="gemma2-2b",
    family="dense",
    n_layers=26,
    d_model=2304,
    n_heads=8,
    n_kv_heads=4,
    d_ff=9216,
    vocab=256000,
    block_pattern=(("attn_local", "mlp"), ("attn", "mlp")),
    extras=(("window", 4096), ("attn_softcap", 50.0)),
    dtype="bfloat16",
    source="arXiv:2408.00118",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab=256, extras=(("window", 8), ("attn_softcap", 50.0)),
        dtype="float32",
    )
