"""qwen2-moe-a2.7b: 24L d=2048 16H, 4 shared + 60 routed top-4, d_ff/exp 1408.

[hf:Qwen/Qwen1.5-MoE-A2.7B; hf]
"""
import dataclasses
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="qwen2-moe-a2.7b",
    family="moe",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=16,
    d_ff=1408,
    vocab=151936,
    block_pattern=(("attn", "moe"),),
    extras=(
        ("moe_d_ff", 1408), ("n_experts", 60), ("topk", 4),
        ("n_shared_experts", 4), ("capacity_factor", 1.25),
    ),
    dtype="bfloat16",
    source="hf:Qwen/Qwen1.5-MoE-A2.7B",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=48,
        vocab=256,
        extras=(
            ("moe_d_ff", 48), ("n_experts", 6), ("topk", 2),
            ("n_shared_experts", 2), ("capacity_factor", 1.5),
        ),
        dtype="float32",
    )
