"""Paper Table 3 model: gpt3_28_3b (layers=62 hidden=6144 heads=48 seq=1024)."""
import dataclasses
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="gpt3_28_3b",
    family="dense",
    n_layers=62,
    d_model=6144,
    n_heads=48,
    n_kv_heads=48,
    d_ff=4 * 6144,
    vocab=50257,
    block_pattern=(("attn", "mlp"),),
    dtype="bfloat16",
    source="ZB paper Table 3",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=4, d_ff=192,
        vocab=256, dtype="float32",
    )
