"""deepseek-v3-671b: 61L d=7168 128H MLA, MoE 1 shared + 256 routed top-8.

d_ff here is the per-expert FF (2048); dense d_ff (first layers) 18432.
MTP omitted (optional head). [arXiv:2412.19437; hf]
"""
import dataclasses
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-v3-671b",
    family="moe",
    n_layers=61,
    d_model=7168,
    n_heads=128,
    n_kv_heads=128,
    d_ff=2048,
    vocab=129280,
    head_dim=128,
    block_pattern=(("mla", "moe"),),
    extras=(
        ("moe_d_ff", 2048), ("n_experts", 256), ("topk", 8),
        ("n_shared_experts", 1), ("capacity_factor", 1.25),
        ("q_lora_rank", 1536), ("kv_lora_rank", 512), ("qk_rope_head_dim", 64),
    ),
    dtype="bfloat16",
    source="arXiv:2412.19437",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=64,
        head_dim=16, vocab=256,
        extras=(
            ("moe_d_ff", 32), ("n_experts", 8), ("topk", 2),
            ("n_shared_experts", 1), ("capacity_factor", 1.5),
            ("q_lora_rank", 32), ("kv_lora_rank", 16), ("qk_rope_head_dim", 8),
        ),
        dtype="float32",
    )
