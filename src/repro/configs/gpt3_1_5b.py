"""Paper Table 3 model: gpt3_1_5b (layers=22 hidden=2304 heads=24 seq=1024)."""
import dataclasses
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="gpt3_1_5b",
    family="dense",
    n_layers=22,
    d_model=2304,
    n_heads=24,
    n_kv_heads=24,
    d_ff=4 * 2304,
    vocab=50257,
    block_pattern=(("attn", "mlp"),),
    dtype="bfloat16",
    source="ZB paper Table 3",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=4, d_ff=192,
        vocab=256, dtype="float32",
    )
