"""internlm2-1.8b: 24L d=2048 16H GQA kv=8 d_ff=8192 vocab=92544.

[arXiv:2403.17297; hf]
"""
import dataclasses
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="internlm2-1.8b",
    family="dense",
    n_layers=24,
    d_model=2048,
    n_heads=16,
    n_kv_heads=8,
    d_ff=8192,
    vocab=92544,
    block_pattern=(("attn", "mlp"),),
    dtype="bfloat16",
    source="arXiv:2403.17297",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab=256, dtype="float32",
    )
