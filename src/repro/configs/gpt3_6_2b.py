"""Paper Table 3 model: gpt3_6_2b (layers=30 hidden=4096 heads=32 seq=1024)."""
import dataclasses
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="gpt3_6_2b",
    family="dense",
    n_layers=30,
    d_model=4096,
    n_heads=32,
    n_kv_heads=32,
    d_ff=4 * 4096,
    vocab=50257,
    block_pattern=(("attn", "mlp"),),
    dtype="bfloat16",
    source="ZB paper Table 3",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=4, d_ff=192,
        vocab=256, dtype="float32",
    )
