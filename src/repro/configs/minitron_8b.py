"""minitron-8b: pruned nemotron, 32L d=4096 32H GQA kv=8 d_ff=16384 vocab=256k.

[arXiv:2407.14679; hf]
"""
import dataclasses
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="minitron-8b",
    family="dense",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=16384,
    vocab=256000,
    block_pattern=(("attn", "mlp"),),
    dtype="bfloat16",
    source="arXiv:2407.14679",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab=512, dtype="float32",
    )
