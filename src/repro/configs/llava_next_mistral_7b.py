"""llava-next-mistral-7b: mistral backbone 32L d=4096 32H GQA kv=8 d_ff=14336.

Anyres vision frontend STUB: input_specs provides precomputed patch
embeddings; a learned projection maps them into the text stream.
[hf:llava-hf/llava-v1.6-mistral-7b-hf; unverified]
"""
import dataclasses
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="llava-next-mistral-7b",
    family="vlm",
    n_layers=32,
    d_model=4096,
    n_heads=32,
    n_kv_heads=8,
    d_ff=14336,
    vocab=32000,
    block_pattern=(("attn", "mlp"),),
    extras=(("n_patches", 576), ("frontend_dim", 1024)),
    dtype="bfloat16",
    source="hf:llava-hf/llava-v1.6-mistral-7b-hf",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=2, d_ff=96,
        vocab=256, extras=(("n_patches", 4), ("frontend_dim", 16)),
        dtype="float32",
    )
