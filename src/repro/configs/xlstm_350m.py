"""xlstm-350m: 24 blocks d=1024 4H, sLSTM + mLSTM mix (xLSTM[7:1]-ish),
d_ff=0 (blocks carry their own projections), vocab 50304.

Sub-quadratic: runs long_500k. [arXiv:2405.04517; unverified]
"""
import dataclasses
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    n_layers=24,
    d_model=1024,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,
    vocab=50304,
    block_pattern=(
        ("mlstm",), ("mlstm",), ("mlstm",), ("slstm",),
    ),
    dtype="bfloat16",
    sub_quadratic=True,
    source="arXiv:2405.04517",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=4, d_model=32, n_heads=2, n_kv_heads=2, vocab=256,
        dtype="float32",
    )
