"""Paper Table 3 model: gpt3_14_6b (layers=46 hidden=5120 heads=40 seq=1024)."""
import dataclasses
from repro.models.lm import ArchConfig

CONFIG = ArchConfig(
    name="gpt3_14_6b",
    family="dense",
    n_layers=46,
    d_model=5120,
    n_heads=40,
    n_kv_heads=40,
    d_ff=4 * 5120,
    vocab=50257,
    block_pattern=(("attn", "mlp"),),
    dtype="bfloat16",
    source="ZB paper Table 3",
)

def reduced() -> ArchConfig:
    return dataclasses.replace(
        CONFIG, n_layers=2, d_model=48, n_heads=4, n_kv_heads=4, d_ff=192,
        vocab=256, dtype="float32",
    )
