"""Architecture registry: 10 assigned architectures + the paper's models.

Each module exposes ``CONFIG`` (the exact published configuration) and
``reduced()`` (a tiny same-family config for CPU smoke tests).  Input-shape
cells for the dry run are defined in ``shapes.py``.
"""

from importlib import import_module
from typing import Dict

from ..models.lm import ArchConfig

ARCH_IDS = [
    "whisper_tiny",
    "deepseek_v3_671b",
    "qwen2_moe_a2_7b",
    "deepseek_67b",
    "minitron_8b",
    "gemma2_2b",
    "internlm2_1_8b",
    "llava_next_mistral_7b",
    "xlstm_350m",
    "recurrentgemma_9b",
]

PAPER_IDS = ["gpt3_1_5b", "gpt3_6_2b", "gpt3_14_6b", "gpt3_28_3b"]


def get_config(arch_id: str) -> ArchConfig:
    mod = import_module(f".{arch_id}", __package__)
    return mod.CONFIG


def get_reduced(arch_id: str) -> ArchConfig:
    mod = import_module(f".{arch_id}", __package__)
    return mod.reduced()


def all_configs() -> Dict[str, ArchConfig]:
    return {a: get_config(a) for a in ARCH_IDS + PAPER_IDS}
