"""Sharded checkpointing with elastic re-shard on restore.

Layout: <dir>/step_<n>/
  manifest.json       -- step, mesh shape, p, n_chunks, leaf index
  chunk<k>.npz        -- per-chunk stage-stacked params (host-gathered)
  shared.npz, opt_*.npz, meta.json

Arrays are saved at *global* (stage-stacked, TP-unsharded... i.e. as the jit
outputs them) shapes, so a restore onto a different mesh / pipeline width is a
pure re-plan: ``reshard_stages`` regroups layer blocks when p changes
(elastic scaling; the ZB auto-scheduler re-searches the schedule for the new
p -- DESIGN.md Sec. 4).
"""

from __future__ import annotations

import json
import os
import shutil
from typing import Any, Dict, Optional, Tuple

import jax
import numpy as np

PyTree = Any

__all__ = ["save", "restore", "latest_step", "reshard_stages"]


def _flatten(tree: PyTree) -> Dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        out[key] = np.asarray(leaf)
    return out


def _unflatten_like(proto: PyTree, data: Dict[str, np.ndarray]) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(proto)
    leaves = []
    for path, leaf in flat:
        key = jax.tree_util.keystr(path)
        arr = data[key]
        leaves.append(np.asarray(arr).astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save(directory: str, step: int, state: Dict[str, PyTree], meta: Optional[dict] = None):
    """Atomic checkpoint write (tmp dir + rename)."""
    final = os.path.join(directory, f"step_{step:08d}")
    tmp = final + ".tmp"
    os.makedirs(tmp, exist_ok=True)
    index = {}
    for name, tree in state.items():
        data = _flatten(tree)
        np.savez(os.path.join(tmp, f"{name}.npz"), **data)
        index[name] = sorted(data.keys())
    with open(os.path.join(tmp, "manifest.json"), "w") as f:
        json.dump({"step": step, "index": index, "meta": meta or {}}, f, indent=1)
    if os.path.exists(final):
        shutil.rmtree(final)
    os.rename(tmp, final)
    return final


def latest_step(directory: str) -> Optional[int]:
    if not os.path.isdir(directory):
        return None
    steps = [
        int(d.split("_")[1])
        for d in os.listdir(directory)
        if d.startswith("step_") and not d.endswith(".tmp")
    ]
    return max(steps) if steps else None


def restore(directory: str, step: int, proto: Dict[str, PyTree]) -> Tuple[Dict[str, PyTree], dict]:
    path = os.path.join(directory, f"step_{step:08d}")
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    state = {}
    for name, tree in proto.items():
        with np.load(os.path.join(path, f"{name}.npz")) as z:
            state[name] = _unflatten_like(tree, dict(z))
    return state, manifest


def reshard_stages(stacked_old, p_old: int, p_new: int):
    """Elastic re-shard: regroup stage-stacked block params for a new p.

    Works when blocks-per-stage changes by an integer factor (the common
    elastic moves p -> p/2 or p -> 2p).  Block leaves have shape
    (p_old, g_old, ...); masks are recomputed by the caller via init_params.
    """
    if p_old == p_new:
        return stacked_old

    def regroup(leaf):
        if leaf.ndim < 2 or leaf.shape[0] != p_old:
            return leaf
        g_old = leaf.shape[1]
        total = p_old * g_old
        if total % p_new:
            raise ValueError(f"cannot reshard {leaf.shape} to p={p_new}")
        g_new = total // p_new
        return np.asarray(leaf).reshape((p_new, g_new) + leaf.shape[2:])

    return jax.tree_util.tree_map(regroup, stacked_old)
