"""ZB-V schedule (paper Sec. 6).

Two chunks per worker placed in a "V": chunk 0 runs stages 0..p-1, chunk 1
runs stages p-1..0.  Both the forward entry (embedding) and the loss exit land
on worker 0, and the first worker starts B without waiting for a p-hop return
trip, which is what buys zero bubble at 1F1B-parity memory (p * M_B) under
T_F = T_B = T_W.

Warm-up (0-indexed worker s): ``min(2p-1-s, m)`` chunk-0 forwards interleaved
with ``min(s, m)`` chunk-1 forwards (in dependency-arrival order).  Steady
state: ``p-1-s`` F-B-W groups of chunk 1, then alternating chunk-1/chunk-0
groups.  Final phase: drain B (prioritized) then W.
"""

from __future__ import annotations

from typing import List, Optional

from .ir import Op, OpKind, Placement, Schedule

__all__ = ["zb_v"]


def _warmup_interleave(p: int, s: int, n0: int, n1: int) -> List[Op]:
    """Order warm-up forwards by their earliest possible start at worker s.

    Chunk-0 F of mb j reaches worker s no earlier than tick s + j; chunk-1 F
    of mb j no earlier than tick (2p - 1 - s) + 2j (down-sweep of the V).
    """
    items = []
    for j in range(n0):
        items.append((s + j, 0, j))
    for j in range(n1):
        items.append((2 * p - 1 - s + 2 * j, 1, j))
    items.sort()
    return [Op(OpKind.F, j, c) for _, c, j in items]


def zb_v(
    p: int,
    m: int,
    times: Optional["TimeModel"] = None,
    m_limit: Optional[float] = None,
    m_b: float = 1.0,
    m_w: float = 0.5,
) -> Schedule:
    """ZB-V via the Sec.-3.1 heuristic on the V placement (paper Sec. 6).

    Defaults to 1F1B-parity memory (``p * M_B``).  Falls back to the explicit
    handcrafted ordering if the heuristic cannot find a feasible schedule.
    """
    from ..simulator import TimeModel
    from .auto import search

    times = times or TimeModel.unit()
    limit = float(p) * m_b if m_limit is None else m_limit
    try:
        res = search(
            p,
            m,
            times,
            m_limit=limit,
            m_b=m_b,
            m_w=m_w,
            placement=Placement.vshape(p),
            name="zb-v",
        )
        res.schedule.name = "zb-v"
        return res.schedule
    except RuntimeError:
        return zb_v_handcrafted(p, m)


def zb_v_handcrafted(p: int, m: int) -> Schedule:
    placement = Placement.vshape(p)
    stage_ops: List[List[Op]] = []
    for s in range(p):
        w0 = min(2 * p - 1 - s, m)
        w1 = min(s, m)
        ops: List[Op] = _warmup_interleave(p, s, w0, w1)
        nf = [w0, w1]  # next F index per chunk
        nb = [0, 0]
        nw = [0, 0]

        def emit_group(c: int) -> None:
            if nf[c] < m:
                ops.append(Op(OpKind.F, nf[c], c))
                nf[c] += 1
            if nb[c] < m:
                ops.append(Op(OpKind.B, nb[c], c))
                nb[c] += 1
            if nw[c] < m:
                ops.append(Op(OpKind.W, nw[c], c))
                nw[c] += 1

        # steady-state init: p-1-s groups of the second chunk
        for _ in range(p - 1 - s):
            if nb[1] >= m:
                break
            emit_group(1)
        # alternate chunk-1 / chunk-0 groups while any forward remains
        turn = 1
        while nf[0] < m or nf[1] < m:
            c = turn if nf[turn] < m or nb[turn] < m else 1 - turn
            emit_group(c)
            turn = 1 - turn
        # drain: B prioritized over W, chunk order by stream progress
        while nb[0] < m or nb[1] < m:
            # pick the chunk whose pending B is "oldest" (smallest index);
            # chunk 1's B becomes available before chunk 0's at every worker.
            if nb[1] < m and (nb[0] >= m or nb[1] <= nb[0]):
                c = 1
            else:
                c = 0
            ops.append(Op(OpKind.B, nb[c], c))
            nb[c] += 1
            if nw[c] < m:
                ops.append(Op(OpKind.W, nw[c], c))
                nw[c] += 1
        for c in (1, 0):
            while nw[c] < m:
                ops.append(Op(OpKind.W, nw[c], c))
                nw[c] += 1
        stage_ops.append(ops)
    return Schedule(p, m, stage_ops, placement=placement, name="zb-v")
