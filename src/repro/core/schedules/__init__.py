from .ir import (
    CHANNEL_BWD_DOWN,
    CHANNEL_BWD_UP,
    CHANNEL_FWD_DOWN,
    CHANNEL_FWD_UP,
    ExecutionPlan,
    MemoryProfile,
    Op,
    OpKind,
    Placement,
    Schedule,
    compile_plan,
)
from .baselines import gpipe, interleaved_1f1b, one_f_one_b
from .handcrafted import zb_h1, zb_h2
from .zbv import zb_v, zb_v_handcrafted
from .vflex import (
    activation_peak,
    stable_v_schedule,
    v_flex,
    v_half,
    v_half_limit,
    v_min,
    v_min_limit,
)
from .auto import AutoResult, search, zb_1p, zb_2p
from .greedy import GreedyConfig, greedy_schedule
from .refine import local_search

__all__ = [
    "CHANNEL_BWD_DOWN",
    "CHANNEL_BWD_UP",
    "CHANNEL_FWD_DOWN",
    "CHANNEL_FWD_UP",
    "ExecutionPlan",
    "MemoryProfile",
    "Op",
    "OpKind",
    "Placement",
    "Schedule",
    "compile_plan",
    "gpipe",
    "interleaved_1f1b",
    "one_f_one_b",
    "zb_h1",
    "zb_h2",
    "zb_v",
    "zb_v_handcrafted",
    "activation_peak",
    "stable_v_schedule",
    "v_flex",
    "v_half",
    "v_half_limit",
    "v_min",
    "v_min_limit",
    "AutoResult",
    "search",
    "zb_1p",
    "zb_2p",
    "GreedyConfig",
    "greedy_schedule",
    "local_search",
]
