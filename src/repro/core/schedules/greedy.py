"""Event-driven greedy schedule construction (paper Sec. 3.1 + Sec. 6).

One engine serves both the linear-placement automatic scheduler (ZB-1p /
ZB-2p style, given a memory limit and profiled T_F/T_B/T_W/T_comm) and the
V-placement ZB-V scheduler.  The engine simulates the pipeline in continuous
time; whenever a stage becomes free it applies the paper's decision rules:

  * warm-up: run as many F as the memory limit allows before the first B;
    a binary hyperparameter (``warmup_extra_f``) controls whether to add an
    F that may delay the incoming first B;
  * steady state: alternate one F and one B; insert W into any gap larger
    than T_W; a hyperparameter (``fill_small_gaps``) also fills sub-T_W gaps;
    insert W when the memory limit blocks the next F;
  * drain: B prioritized, W fills the tail.

The constructed op *ordering* is returned as a Schedule; exact timing is then
re-derived by the simulator/executor.  A grid search over the binary
hyperparameters (paper Sec. 3.1 last bullet) is provided by
:func:`auto.search`.
"""

from __future__ import annotations

import dataclasses
import heapq
from typing import Dict, List, Optional, Tuple

from .ir import Op, OpKind, Placement, Schedule

if False:  # typing only; runtime import would be circular
    from ..simulator import TimeModel

__all__ = ["GreedyConfig", "greedy_schedule"]

_INF = float("inf")


@dataclasses.dataclass(frozen=True)
class GreedyConfig:
    m_limit: float  # activation memory limit, units of full-stage M_B
    m_b: float = 1.0  # full-stage M_B
    m_w: float = 0.5  # full-stage M_W
    warmup_extra_f: bool = True  # paper hyperparam 1
    fill_small_gaps: bool = True  # paper hyperparam 2
    prefer_f_on_tie: bool = False  # tie-break when both F and B runnable
    eager_w: bool = False  # run W instead of idling even outside gaps rule
    drain_strict_w: bool = False  # in the drain, only insert W into >=T_W gaps
    #   ("shift W right", paper Sec. 6 -- a sub-T_W W delays the whole B wave)


def greedy_schedule(
    p: int,
    m: int,
    times: "TimeModel",
    cfg: GreedyConfig,
    placement: Optional[Placement] = None,
    name: str = "greedy",
) -> Schedule:
    pl = placement or Placement.linear(p)
    C = pl.n_chunks
    mb_c = cfg.m_b / C  # per-chunk-pass memory
    mw_c = cfg.m_w / C

    dur = {
        OpKind.F: times.t_f / C,
        OpKind.B: times.t_b / C,
        OpKind.W: times.t_w / C,
    }
    tc = times.t_comm

    # availability times of inputs
    arr_f: Dict[Tuple[int, int, int], float] = {}  # (stage, chunk, mb) -> t
    arr_b: Dict[Tuple[int, int, int], float] = {}
    for j in range(m):
        arr_f[(pl.stage_of(0, 0), 0, j)] = 0.0

    clock = [0.0] * p
    mem = [0.0] * p
    nf = [[0] * C for _ in range(p)]  # next F index per (stage, chunk)
    nb = [[0] * C for _ in range(p)]
    nw = [[0] * C for _ in range(p)]
    seen_b = [False] * p  # has this stage run any B yet (warm-up tracking)
    last_kind = [OpKind.B] * p  # alternation state; start wanting F
    ops_out: List[List[Op]] = [[] for _ in range(p)]
    done = [0] * p
    total_per_stage = 3 * m * C

    def scale(s: int) -> float:
        return times.stage_scale[s] if times.stage_scale is not None else 1.0

    def commit(s: int, kind: OpKind, c: int, t_start: float) -> None:
        j = {OpKind.F: nf, OpKind.B: nb, OpKind.W: nw}[kind][s][c]
        t_end = t_start + dur[kind] * scale(s)
        ops_out[s].append(Op(kind, j, c))
        clock[s] = t_end
        done[s] += 1
        if kind == OpKind.F:
            nf[s][c] += 1
            mem[s] += mb_c
            nxt = pl.fwd_next(c, pl.pos_of(c, s))
            if nxt is None:
                arr_b[(s, c, j)] = t_end  # loss: B can start immediately
            else:
                ns = pl.stage_of(*nxt)
                arr_f[(ns, nxt[0], j)] = t_end + (0.0 if ns == s else tc)
        elif kind == OpKind.B:
            nb[s][c] += 1
            mem[s] += mw_c - mb_c
            seen_b[s] = True
            prev = pl.fwd_prev(c, pl.pos_of(c, s))
            if prev is not None:
                ps = pl.stage_of(*prev)
                arr_b[(ps, prev[0], j)] = t_end + (0.0 if ps == s else tc)
        else:
            nw[s][c] += 1
            mem[s] -= mw_c
        if kind != OpKind.W:
            last_kind[s] = kind

    def hops_to_loss(s: int, c: int) -> int:
        """F-chain distance from (chunk c at stage s) to the loss pass."""
        k = pl.pos_of(c, s)
        return (pl.p - 1 - k) + (C - 1 - c) * pl.p

    # Warm-up F cap per (stage, chunk): running more forwards of a shallow
    # chunk than its loss distance would push back the deeper chunk's F wave
    # (and with it the first B) by T_F per extra pass.  For the V placement
    # this reproduces the paper's 2p-1-s / s warm-up split exactly.
    extra = 1 if cfg.warmup_extra_f else 0
    warm_cap = [
        [hops_to_loss(s, c) + extra for c in range(C)] for s in range(p)
    ]

    def f_fits(s: int, c: int) -> bool:
        """Memory check with reservation: chunk c may not squeeze out deeper
        chunks' forwards -- one slot stays reserved per deeper chunk, else the
        loss-producing F (and with it the whole B chain) can deadlock."""
        reserve = (C - 1 - c) * mb_c
        return mem[s] + mb_c <= cfg.m_limit - reserve + 1e-9

    def f_candidates(s: int) -> List[Tuple[float, int]]:
        out = []
        for c in range(C):
            if nf[s][c] < m:
                t = arr_f.get((s, c, nf[s][c]))
                if t is not None:
                    out.append((t, c))
        return out

    def b_candidates(s: int) -> List[Tuple[float, int]]:
        out = []
        for c in range(C):
            if nb[s][c] < m and nb[s][c] < nf[s][c]:
                t = arr_b.get((s, c, nb[s][c]))
                if t is not None:
                    out.append((t, c))
        return out

    def w_candidate(s: int) -> Optional[int]:
        for c in reversed(range(C)):
            if nw[s][c] < nb[s][c]:
                return c
        return None

    def decide(s: int) -> Tuple[float, Optional[Tuple[OpKind, int]]]:
        """Return (time, action); action None means 're-decide at time'."""
        t = clock[s]
        fs = f_candidates(s)
        bs = b_candidates(s)
        wc = w_candidate(s)
        # runnable F passes: arrived and fitting memory; deepest chunk first.
        # Before the first B, shallow chunks respect their warm-up cap so the
        # deeper chunk's wave (which carries the loss) is never displaced.
        f_run = [
            c
            for (a, c) in fs
            if a <= t
            and f_fits(s, c)
            and (seen_b[s] or c == C - 1 or nf[s][c] < warm_cap[s][c])
        ]
        f_pick = max(f_run) if f_run else None
        f_blocked = any(a <= t and not f_fits(s, c) for (a, c) in fs)
        f_waits = [a for (a, c) in fs if a > t]
        # runnable B passes: earliest arrival, deeper chunk on ties
        b_run = sorted(((a, -c) for (a, c) in bs if a <= t))
        b_pick = -b_run[0][1] if b_run else None
        b_waits = [a for (a, c) in bs if a > t]
        w_now = wc is not None

        if not seen_b[s]:
            # warm-up: pack F passes under the memory limit (paper rule 1)
            if f_pick is not None and b_pick is None:
                first_b = min(b_waits) if b_waits else None
                delay_first_b = (
                    first_b is not None
                    and t + dur[OpKind.F] * scale(s) > first_b
                )
                if not delay_first_b or cfg.warmup_extra_f:
                    return (t, (OpKind.F, f_pick))
            if b_pick is not None:
                return (t, (OpKind.B, b_pick))
            waits = f_waits + b_waits
            if w_now and cfg.eager_w:
                return (t, (OpKind.W, wc))
            if waits:
                return (min(waits), None)
            if w_now:
                return (t, (OpKind.W, wc))
            return (_INF, None)

        # steady state: one F, one B iteratively
        want = OpKind.F if last_kind[s] == OpKind.B else OpKind.B
        if want == OpKind.F and f_pick is not None:
            return (t, (OpKind.F, f_pick))
        if want == OpKind.B and b_pick is not None:
            return (t, (OpKind.B, b_pick))
        # desired kind not runnable: fall back to the other
        if b_pick is not None and f_pick is not None:
            k = (OpKind.F, f_pick) if cfg.prefer_f_on_tie else (OpKind.B, b_pick)
            return (t, k)
        if b_pick is not None:
            return (t, (OpKind.B, b_pick))
        if f_pick is not None:
            return (t, (OpKind.F, f_pick))
        # memory-blocked F with nothing else: recycle memory with W
        if f_blocked and w_now:
            return (t, (OpKind.W, wc))
        # gap: decide W vs wait (paper rule 2)
        waits = f_waits + b_waits
        if not waits:
            if w_now:
                return (t, (OpKind.W, wc))
            return (_INF, None)  # wait for an unseen arrival
        gap = min(waits) - t
        # During the drain (no forwards left on this stage) a W that overruns
        # the gap delays the B wave for every downstream stage; with
        # drain_strict_w, insert W only when it fits ("shift W right", Sec. 6).
        drain = cfg.drain_strict_w and all(nf[s][c] >= m for c in range(C))
        if w_now and (
            gap >= dur[OpKind.W] * scale(s) - 1e-9
            or (not drain and (cfg.fill_small_gaps or cfg.eager_w))
        ):
            return (t, (OpKind.W, wc))
        return (min(waits), None)

    # global event loop
    remaining = sum(total_per_stage - d for d in done)
    guard = 0
    while remaining > 0:
        guard += 1
        if guard > 40 * p * m * C + 10000:
            raise RuntimeError("greedy scheduler failed to converge")
        best_s, best_t, best_a = -1, _INF, None
        for s in range(p):
            if done[s] >= total_per_stage:
                continue
            t, a = decide(s)
            ts = max(t, clock[s]) if a is not None else t
            if ts < best_t or (ts == best_t and a is not None and best_a is None):
                best_s, best_t, best_a = s, ts, a
        if best_a is None:
            if best_t is _INF or best_s < 0:
                state = {
                    s: dict(
                        done=done[s],
                        mem=round(mem[s], 2),
                        nf=list(nf[s]),
                        nb=list(nb[s]),
                        nw=list(nw[s]),
                        clock=round(clock[s], 2),
                        decide=decide(s),
                        cand=(f_candidates(s), b_candidates(s), w_candidate(s)),
                    )
                    for s in range(p)
                    if done[s] < total_per_stage
                }
                raise RuntimeError(f"greedy scheduler deadlocked: {state}")
            clock[best_s] = max(clock[best_s], best_t)
            continue
        kind, c = best_a
        commit(best_s, kind, c, max(best_t, clock[best_s]))
        remaining -= 1

    return Schedule(p, m, ops_out, placement=pl, name=name)
