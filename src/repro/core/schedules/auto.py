"""Automatic pipeline scheduling (paper Sec. 3).

``search`` runs the Sec.-3.1 heuristic over the binary-hyperparameter grid
(the paper's final bullet) and returns the schedule with the lowest simulated
cost; ``refine`` (see refine.py) optionally polishes it with local search, the
stand-in for the paper's ILP (Appendix G) in this solver-free environment.

The two canonical memory limits from the paper:
  * ZB-1p: ``M_limit = p * M_B``   (1F1B-parity memory)
  * ZB-2p: ``M_limit = 2p * M_B``  (empirical threshold for ~zero bubble)
"""

from __future__ import annotations

import dataclasses
import itertools
from typing import Optional, Tuple

from .greedy import GreedyConfig, greedy_schedule
from .ir import Placement, Schedule

if False:  # typing only
    from ..simulator import TimeModel

__all__ = ["AutoResult", "search", "zb_1p", "zb_2p"]


@dataclasses.dataclass
class AutoResult:
    schedule: Schedule
    cost: float
    bubble_rate: float
    config: GreedyConfig


def search(
    p: int,
    m: int,
    times: "TimeModel",
    m_limit: float,
    m_b: float = 1.0,
    m_w: float = 0.5,
    placement: Optional[Placement] = None,
    name: str = "zb-auto",
    refine_steps: int = 0,
) -> AutoResult:
    """Grid-search the heuristic's binary hyperparameters (paper Sec. 3.1).

    ``placement`` may also be the string ``"v_flex"``: the search then runs
    on the two-chunk V placement and additionally enters the
    controllable-memory ``v_flex`` portfolio (arXiv 2405.15362) as a
    candidate, decided against the greedy grid by simulated cost (the
    portfolio is consulted via the on-disk plan cache, so a second process
    replays it).  Every returned schedule still honors ``m_limit`` on the
    op-count memory profile.
    """
    from ..simulator import simulate

    v_flex_mode = placement == "v_flex"
    if v_flex_mode:
        placement = Placement.vshape(p)

    best: Optional[AutoResult] = None
    grid = itertools.product([True, False], repeat=5)
    for warm_extra, fill_small, prefer_f, eager_w, drain_strict in grid:
        cfg = GreedyConfig(
            m_limit=m_limit,
            m_b=m_b,
            m_w=m_w,
            warmup_extra_f=warm_extra,
            fill_small_gaps=fill_small,
            prefer_f_on_tie=prefer_f,
            eager_w=eager_w,
            drain_strict_w=drain_strict,
        )
        try:
            sched = greedy_schedule(p, m, times, cfg, placement, name=name)
            res = simulate(sched, times)
        except (RuntimeError, ValueError):
            continue
        if best is None or res.cost < best.cost:
            best = AutoResult(sched, res.cost, res.bubble_rate, cfg)
    # Portfolio: the handcrafted schedules are valid candidates whenever they
    # fit the memory limit (the paper itself observes ZB-1p == ZB-H1 when the
    # memory limit dominates).
    handcrafted = []
    if placement is None or placement.n_chunks == 1:
        from .handcrafted import zb_h1, zb_h2

        handcrafted = [zb_h1(p, m), zb_h2(p, m)]
    elif placement == Placement.vshape(p):
        from .zbv import zb_v_handcrafted

        handcrafted = [zb_v_handcrafted(p, m)]
    for sched in handcrafted:
        peak = sched.memory_profile(
            m_b / sched.n_chunks, m_w / sched.n_chunks
        ).max_peak
        if peak > m_limit + 1e-9:
            continue
        res = simulate(sched, times)
        if best is None or res.cost < best.cost:
            sched.name = name
            best = AutoResult(sched, res.cost, res.bubble_rate, GreedyConfig(m_limit))
    if v_flex_mode:
        from .vflex import v_flex

        # the portfolio caps the activation component; keep only candidates
        # whose *combined* (act + wctx) profile honors m_limit, so the
        # m_limit contract matches the grid's.  The full-limit cap is tried
        # first and smaller caps only when it overshoots the combined
        # profile (each cap is a whole portfolio build -- disk-cached, but
        # the first build must stay interactive).  Simulated cost decides
        # the tie-break against the greedy grid (ties go to v_flex: at
        # equal cost it additionally bounds the activation peak).
        limit_units = m_limit / m_b if m_b > 0 else m_limit
        for frac in (1.0, 0.75, 0.5):
            al = limit_units * frac
            if al < 1.0:
                continue
            try:
                sched = v_flex(p, m, al, times=times, name=name)
            except (ValueError, RuntimeError):
                continue
            peak = sched.memory_profile(
                m_b / sched.n_chunks, m_w / sched.n_chunks
            ).max_peak
            if peak > m_limit + 1e-9:
                continue  # wctx overshoot: retry with a tighter act cap
            res = simulate(sched, times)
            if best is None or res.cost <= best.cost + 1e-9:
                best = AutoResult(
                    sched, res.cost, res.bubble_rate, GreedyConfig(m_limit)
                )
            break  # first cap whose combined profile fits is enough
    if best is None:
        raise RuntimeError(f"no feasible schedule found (p={p}, m={m}, limit={m_limit})")
    if refine_steps > 0:
        from .refine import local_search

        refined = local_search(best.schedule, times, max_steps=refine_steps)
        res = simulate(refined, times)
        if res.cost < best.cost:
            best = AutoResult(refined, res.cost, res.bubble_rate, best.config)
    return best


def zb_1p(p: int, m: int, times=None, **kw) -> Schedule:
    """Auto schedule at 1F1B-parity memory (paper's ZB-1p)."""
    from ..simulator import TimeModel

    times = times or TimeModel.unit()
    r = search(p, m, times, m_limit=float(p), name="zb-1p", **kw)
    r.schedule.name = "zb-1p"
    return r.schedule


def zb_2p(p: int, m: int, times=None, **kw) -> Schedule:
    """Auto schedule at 2x memory (paper's ZB-2p, ~zero bubble)."""
    from ..simulator import TimeModel

    times = times or TimeModel.unit()
    r = search(p, m, times, m_limit=2.0 * p, name="zb-2p", **kw)
    r.schedule.name = "zb-2p"
    return r.schedule
