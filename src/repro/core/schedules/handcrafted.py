"""Handcrafted zero-bubble schedules ZB-H1 and ZB-H2 (paper Sec. 2).

Both are "delayed-W 1F1B" variants: the backward is split, the B wave
propagates at T_B per hop (instead of T_B + T_W), and each stage defers its W
passes by a stage-dependent amount so W fills what would otherwise be
bubbles.

  * ZB-H1: warm-up identical to 1F1B (p-1-s forwards); stage s defers W_k
    until after B_{k+s}.  In-flight microbatches stay at p on every stage, so
    peak activation memory matches 1F1B (p * M_B).  Bubble:
    (p-1)(T_F + T_B - T_W).
  * ZB-H2: warm-up extended to 2(p-s)-3+... precisely min(m, 2p-1-2s)
    forwards, steady phase is B-then-F, and stage s defers W_k until after
    B_{k+2s}; the layout becomes a parallelogram with zero bubble under
    T_F = T_B = T_W at (2p-1) * M_B peak memory.
"""

from __future__ import annotations

from typing import Callable, List

from .ir import Op, OpKind, Schedule

__all__ = ["zb_h1", "zb_h2"]


def _delayed_w(
    p: int,
    m: int,
    warmup: Callable[[int], int],
    w_delay: Callable[[int], int],
    b_first: bool,
    name: str,
) -> Schedule:
    stage_ops: List[List[Op]] = []
    for s in range(p):
        warm = max(0, min(warmup(s), m))
        delay = w_delay(s)
        ops: List[Op] = [Op(OpKind.F, j) for j in range(warm)]
        w_next = 0
        for j in range(m):
            if b_first:
                # B, then due W passes, then F: keeps the steady-state peak at
                # the warm-up level (no +M_W transient above (2p-1) M_B).
                ops.append(Op(OpKind.B, j))
                while w_next <= j - delay and w_next < m:
                    ops.append(Op(OpKind.W, w_next))
                    w_next += 1
                if warm + j < m:
                    ops.append(Op(OpKind.F, warm + j))
            else:
                if warm + j < m:
                    ops.append(Op(OpKind.F, warm + j))
                ops.append(Op(OpKind.B, j))
                while w_next <= j - delay and w_next < m:
                    ops.append(Op(OpKind.W, w_next))
                    w_next += 1
        ops += [Op(OpKind.W, k) for k in range(w_next, m)]
        stage_ops.append(ops)
    return Schedule(p, m, stage_ops, name=name)


def zb_h1(p: int, m: int) -> Schedule:
    """Memory-efficient handcrafted schedule (paper Sec. 2.1)."""
    return _delayed_w(
        p,
        m,
        warmup=lambda s: p - 1 - s,
        w_delay=lambda s: s,
        b_first=False,
        name="zb-h1",
    )


def zb_h2(p: int, m: int) -> Schedule:
    """Zero-bubble handcrafted schedule (paper Sec. 2.2)."""
    return _delayed_w(
        p,
        m,
        warmup=lambda s: 2 * p - 1 - 2 * s,
        w_delay=lambda s: 2 * s,
        b_first=True,
        name="zb-h2",
    )
