"""Local-search schedule refinement -- the ILP stand-in (paper Appendix G).

The paper formulates exact schedule optimization as an ILP solved with
COIN-OR CBC; no solver ships in this offline environment, so we polish the
heuristic's output with deterministic first-improvement local search over op
*orderings*, evaluated by the exact discrete-event simulator.  Moves:

  * swap two adjacent ops on one stage (when dependency-valid),
  * pull a W pass earlier / push it later within its stage program.

On the paper's own settings the heuristic alone already reaches the reported
ZB-2p bubble rates (see EXPERIMENTS.md), matching the paper's observation
that the ILP is a small-scale polish; local search closes what remains on
small/awkward (p, m) combinations.
"""

from __future__ import annotations

import copy
from typing import List, Optional

from .ir import Op, OpKind, Schedule

__all__ = ["local_search"]


def _try(sched: Schedule, stage_ops: List[List[Op]], times):
    from ..simulator import simulate

    try:
        cand = Schedule(
            sched.p,
            sched.m,
            stage_ops,
            placement=sched.placement,
            name=sched.name,
        )
        return simulate(cand, times).cost, cand
    except (ValueError, RuntimeError):
        return None


def local_search(
    sched: Schedule,
    times,
    max_steps: int = 200,
    m_limit: Optional[float] = None,
    m_b: float = 1.0,
    m_w: float = 0.5,
) -> Schedule:
    from ..simulator import simulate

    best = sched
    best_cost = simulate(sched, times).cost
    steps = 0
    improved = True
    while improved and steps < max_steps:
        improved = False
        for s in range(best.p):
            ops = best.stage_ops[s]
            for i in range(len(ops) - 1):
                a, b = ops[i], ops[i + 1]
                if a.kind == b.kind and a.kind == OpKind.W:
                    continue  # W/W swaps never help (identical costs)
                new_ops = [list(o) for o in best.stage_ops]
                new_ops[s] = ops[:i] + [b, a] + ops[i + 2 :]
                res = _try(best, new_ops, times)
                if res is None:
                    continue
                cost, cand = res
                if m_limit is not None:
                    peak = cand.memory_profile(
                        m_b / cand.n_chunks, m_w / cand.n_chunks
                    ).max_peak
                    if peak > m_limit + 1e-9:
                        continue
                if cost < best_cost - 1e-9:
                    best, best_cost = cand, cost
                    improved = True
                    steps += 1
                    break
            if improved:
                break
    return best
