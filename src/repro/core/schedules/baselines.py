"""Baseline pipeline schedules: GPipe, 1F1B, interleaved 1F1B.

These are the methods the paper compares against (Sec. 5.1).  In the IR every
backward is split into B and W; the classic fused-backward semantics of these
baselines is recovered by simulating them with ``TimeModel(grouped_w=True)``
(W duration folded into B, so the activation-gradient send waits for the full
backward -- exactly Megatron's behaviour).
"""

from __future__ import annotations

from typing import List

from .ir import Op, OpKind, Placement, Schedule

__all__ = ["gpipe", "one_f_one_b", "interleaved_1f1b"]


def gpipe(p: int, m: int) -> Schedule:
    """All forwards, then all backwards (Huang et al., 2019)."""
    stage_ops: List[List[Op]] = []
    for _s in range(p):
        ops = [Op(OpKind.F, j) for j in range(m)]
        for j in range(m):
            ops += [Op(OpKind.B, j), Op(OpKind.W, j)]
        stage_ops.append(ops)
    return Schedule(p, m, stage_ops, name="gpipe")


def one_f_one_b(p: int, m: int) -> Schedule:
    """Megatron-style non-interleaved 1F1B (Fan 2021; Narayanan 2021).

    Stage s runs ``p - 1 - s`` warm-up forwards, then alternates F/B with the
    weight pass immediately after each B (fused backward).
    """
    stage_ops: List[List[Op]] = []
    for s in range(p):
        warm = min(p - 1 - s, m)
        ops = [Op(OpKind.F, j) for j in range(warm)]
        for j in range(m):
            if warm + j < m:
                ops.append(Op(OpKind.F, warm + j))
            ops += [Op(OpKind.B, j), Op(OpKind.W, j)]
        stage_ops.append(ops)
    return Schedule(p, m, stage_ops, name="1f1b")


def interleaved_1f1b(p: int, m: int, v: int = 2) -> Schedule:
    """Megatron interleaved 1F1B with ``v`` chunks per stage.

    Requires ``m % p == 0`` (Megatron's constraint).  Virtual microbatches are
    walked in groups of ``p``: group g covers chunk ``g % v`` of microbatches
    ``(g // v) * p .. (g // v) * p + p - 1``.
    """
    if m % p != 0:
        raise ValueError(f"interleaved 1F1B requires m % p == 0 (m={m}, p={p})")
    if v < 2:
        raise ValueError("interleaved needs v >= 2 chunks")
    total = m * v

    def fwd_virtual(k: int) -> Op:
        g, r = divmod(k, p)
        chunk = g % v
        mb = (g // v) * p + r
        return Op(OpKind.F, mb, chunk)

    def bwd_virtual(k: int) -> Op:
        g, r = divmod(k, p)
        chunk = v - 1 - (g % v)
        mb = (g // v) * p + r
        return Op(OpKind.B, mb, chunk)

    stage_ops: List[List[Op]] = []
    for s in range(p):
        warm = min((p - s - 1) * 2 + (v - 1) * p, total)
        ops: List[Op] = [fwd_virtual(k) for k in range(warm)]
        nf, nb = warm, 0
        while nb < total:
            if nf < total:
                ops.append(fwd_virtual(nf))
                nf += 1
            b = bwd_virtual(nb)
            ops.append(b)
            ops.append(Op(OpKind.W, b.mb, b.chunk))
            nb += 1
        stage_ops.append(ops)
    return Schedule(
        p,
        m,
        stage_ops,
        placement=Placement.linear(p, v),
        name=f"1f1b-interleaved-v{v}",
    )
