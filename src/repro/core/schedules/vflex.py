"""Controllable-memory V schedules: V-Min / V-Half (arXiv 2405.15362).

The follow-up to the zero-bubble paper shows the activation-memory /
throughput trade-off of pipeline schedules is a continuum governed by the
*lifespan* of each microbatch's activations: on the two-chunk V placement
(chunk 0 runs stages 0..p-1, chunk 1 runs p-1..0, like ZB-V) the steady state
is a repeating 6-slot pattern per microbatch -- F, f, b, B plus two W slots --
and shrinking the F->B lifespans shrinks the per-stage activation peak:

  * V-Min  : ~p/3 of 1F1B's activation memory (minimal: the pattern's
             lifespans are as short as the dependency chain allows),
  * V-Half : ~p/2, with near-zero bubbles.

Two constructions are provided:

1. :func:`stable_v_schedule` -- the paper's construction verbatim: per-stage
   *stable pattern* offsets repeated with period 6, W passes greedily placed
   into the free slots (the ``put_w`` idea of the reference implementation).
   This realizes the steady state exactly but ramps in/out at the pattern
   rate, so its bubble is larger than necessary.

2. :func:`v_flex` -- an event-driven greedy on the V placement with the
   pattern's memory bound enforced as an *activation cap* (in-flight F-minus-B
   chunk passes per stage) plus two structural rules learned from the
   pattern:

     * dual admission gate for chunk-0 forwards: a warm-up count before the
       first B0 retires (clipped ZB-V counts, so deep stages never fill
       themselves and stall the returning chunk-1 wave), then a steady
       *lead* over the stage's own B0 retirements (the pattern's lifespan
       control);
     * B passes always first (they free activations and drive both waves),
       chunk-1 F before chunk-0 F (the returning wave carries the loss),
       W passes fill memory stalls and gaps, with a bounded drain-time bank.

   A small deterministic portfolio of gate shapes is simulated and the
   fastest schedule whose *activation* peak fits the limit is returned,
   followed by a cost-neutral W-compaction that pulls W passes earlier to
   shrink the B->W context backlog.

Peak accounting note: the limits bound the *activation* component (the
paper's M_B term, freed at B).  The B->W context (M_W, the ZB paper's kept
cotangents) is tracked separately by :mod:`repro.core.memory`; W-compaction
keeps it small but it is not part of the V-Min/V-Half contract.

``v_min``/``v_half`` meet, simulator-verified under T_F = T_B = T_W and
t_comm = 0 (see tests/test_memory.py):

  peak_act(v_min)  <= ceil(p * M_B / 3) + 2 * M_B
  peak_act(v_half) <= ceil(p * M_B / 2) + 2 * M_B
  bubble_rate(v_*) <= bubble_rate(zb_h1)        for p in {4, 6, 8}, m >= 2p.
"""

from __future__ import annotations

import functools
import math
from collections import deque
from typing import Callable, Dict, List, Optional, Sequence, Tuple

from .ir import Op, OpKind, Placement, Schedule

__all__ = [
    "v_min",
    "v_half",
    "v_flex",
    "v_min_limit",
    "v_half_limit",
    "stable_v_schedule",
    "stable_pattern",
    "activation_peak",
]

_INF = float("inf")
_CYCLE = 6  # slots per microbatch per stage in the steady pattern


# --------------------------------------------------------------------- #
# activation peak (the controllable quantity)
# --------------------------------------------------------------------- #
def activation_peak(schedule: Schedule, m_b: float = 1.0) -> float:
    """Peak of the M_B component per stage: F allocates, B frees.

    ``m_b`` is the *full-stage* activation; each chunk pass moves
    ``m_b / n_chunks``.  This is the quantity V-Min/V-Half bound; the B->W
    context is accounted separately (see repro.core.memory).
    """
    mb_c = m_b / schedule.n_chunks
    peak = 0.0
    for ops in schedule.stage_ops:
        cur = 0.0
        for op in ops:
            if op.kind == OpKind.F:
                cur += mb_c
            elif op.kind == OpKind.B:
                cur -= mb_c
            peak = max(peak, cur)
    return peak


# --------------------------------------------------------------------- #
# 1. the paper's stable-pattern construction
# --------------------------------------------------------------------- #
def stable_pattern(p: int, kind: str) -> List[Tuple[int, int, int, int]]:
    """Per-stage steady-state offsets (F0, F1, B1, B0) within one cycle.

    The offsets are the reference implementation's ``stable_pattern_v_min`` /
    ``v_half`` tables: consecutive microbatches repeat them with period 6,
    and the ``interval`` term keeps the four compute slots of one stage on
    distinct residues mod 6 (otherwise two passes of different microbatches
    would collide in the same slot).
    """
    if kind == "v-min":
        iv = 2 if p % 3 == 0 else 0
        rows = [
            (i, 2 * p - 1 - i, 2 * p + iv + i, 4 * p + iv - 1 - i)
            for i in range(p)
        ]
    elif kind == "v-half":
        iv = 3 if p % 2 == 0 else 0
        rows = [
            (2 * i, 3 * p - i - 2, 3 * p + iv + 2 * i - 1, 6 * p + iv - i - 2)
            for i in range(p)
        ]
    else:
        raise ValueError(f"unknown stable pattern kind {kind!r}")
    for i, row in enumerate(rows):
        if len({t % _CYCLE for t in row}) != 4:
            raise ValueError(
                f"{kind} pattern collides mod {_CYCLE} at stage {i}: {row}"
            )
    return rows


def stable_v_schedule(p: int, m: int, kind: str = "v-min") -> Schedule:
    """Repeat the stable pattern for m microbatches; W fills free slots.

    W placement is the greedy ``put_w``: walk the integer slots in time
    order; every slot not taken by a compute pass pops the oldest pending
    (B done, W not) microbatch.
    """
    offsets = stable_pattern(p, kind)
    stage_ops: List[List[Op]] = []
    for s in range(p):
        t_f0, t_f1, t_b1, t_b0 = offsets[s]
        events: Dict[int, Op] = {}
        for j in range(m):
            base = _CYCLE * j
            for t, op in (
                (t_f0 + base, Op(OpKind.F, j, 0)),
                (t_f1 + base, Op(OpKind.F, j, 1)),
                (t_b1 + base, Op(OpKind.B, j, 1)),
                (t_b0 + base, Op(OpKind.B, j, 0)),
            ):
                events[t] = op
        pending: deque = deque()
        ops: List[Op] = []
        t = 0
        horizon = max(events) + 1
        while t < horizon or pending:
            op = events.get(t)
            if op is not None:
                ops.append(op)
                if op.kind == OpKind.B:
                    pending.append(op)
            elif pending:
                b = pending.popleft()
                ops.append(Op(OpKind.W, b.mb, b.chunk))
            t += 1
        stage_ops.append(ops)
    return Schedule(p, m, stage_ops, placement=Placement.vshape(p), name=kind)


# --------------------------------------------------------------------- #
# 2. memory-capped event-driven greedy on the V placement
# --------------------------------------------------------------------- #
def _v_greedy(
    p: int,
    m: int,
    act_cap: int,  # activation cap per stage, in chunk passes
    warm_lead: Sequence[int],  # per-stage warm-up count == steady F0 lead
    reserve: int = 1,  # chunk-pass headroom chunk-0 F must leave for the wave
    bank_w: bool = False,  # bank W passes for the drain's B0 arrival gaps
    bank_cap: int = 4,  # max banked (B done, W pending) chunk passes
    name: str = "v-flex",
) -> Schedule:
    pl = Placement.vshape(p)
    arr_f: Dict[Tuple[int, int, int], float] = {}
    arr_b: Dict[Tuple[int, int, int], float] = {}
    for j in range(m):
        arr_f[(0, 0, j)] = 0.0
    clock = [0.0] * p
    act = [0] * p  # in-flight chunk passes (F issued, B not done)
    nf = [[0, 0] for _ in range(p)]
    nb = [[0, 0] for _ in range(p)]
    nw = [[0, 0] for _ in range(p)]
    ops_out: List[List[Op]] = [[] for _ in range(p)]
    done = [0] * p
    total = 6 * m

    def commit(s: int, kind: OpKind, c: int, t: float) -> None:
        j = {OpKind.F: nf, OpKind.B: nb, OpKind.W: nw}[kind][s][c]
        te = t + 1.0
        ops_out[s].append(Op(kind, j, c))
        clock[s] = te
        done[s] += 1
        if kind == OpKind.F:
            nf[s][c] += 1
            act[s] += 1
            nxt = pl.fwd_next(c, pl.pos_of(c, s))
            if nxt is None:
                arr_b[(s, c, j)] = te  # loss: B seeds immediately
            else:
                arr_f[(pl.stage_of(*nxt), nxt[0], j)] = te
        elif kind == OpKind.B:
            nb[s][c] += 1
            act[s] -= 1
            prev = pl.fwd_prev(c, pl.pos_of(c, s))
            if prev is not None:
                arr_b[(pl.stage_of(*prev), prev[0], j)] = te
        else:
            nw[s][c] += 1

    def decide(s: int) -> Tuple[float, Optional[Tuple[OpKind, int]]]:
        t = clock[s]
        # returning chunk-1 wave first: it carries the loss round trip
        if nf[s][1] < m:
            a = arr_f.get((s, 1, nf[s][1]))
            if a is not None and a <= t and act[s] + 1 <= act_cap:
                return (t, (OpKind.F, 1))
        # B passes: free activations and drive both waves; earliest arrival
        bs = []
        for c in (1, 0):
            if nb[s][c] < nf[s][c]:
                a = arr_b.get((s, c, nb[s][c]))
                if a is not None:
                    bs.append((a, c))
        b_now = sorted((a, -c) for a, c in bs if a <= t)
        if b_now:
            return (t, (OpKind.B, -b_now[0][1]))
        # chunk-0 F: memory headroom + dual admission gate
        f_cands = []
        for c in (1, 0):
            if nf[s][c] < m:
                a = arr_f.get((s, c, nf[s][c]))
                if a is not None:
                    f_cands.append((a, c))
        for a, c in f_cands:
            if a > t:
                continue
            need = 1 + (reserve if c == 0 else 0)
            if act[s] + need > act_cap:
                continue
            if c == 0:
                lead = warm_lead[s]
                wcount = max(1, min(lead, 2 * p - 1 - s))
                if not (
                    nf[s][0] < lead + nb[s][0]
                    or (nb[s][0] == 0 and nf[s][0] < wcount)
                ):
                    continue
            return (t, (OpKind.F, c))
        # W: fill memory stalls and gaps
        w_c = None
        for c in (1, 0):
            if nw[s][c] < nb[s][c]:
                w_c = c
                break
        waits = [a for a, _ in bs if a > t] + [a for a, c in f_cands if a > t]
        backlog = (nb[s][0] - nw[s][0]) + (nb[s][1] - nw[s][1])
        in_drain = nf[s][0] >= m and nf[s][1] >= m
        if (
            bank_w
            and in_drain
            and (nb[s][0] < m or nb[s][1] < m)
            and backlog < bank_cap
        ):
            # bank W passes for the final B0 arrival gaps ("shift W right")
            if waits:
                return (min(waits), None)
            if w_c is not None and backlog > 2 * m - nb[s][0] - nb[s][1]:
                return (t, (OpKind.W, w_c))
            return (t + 1.0, None)
        # neither B nor F can issue right now: a pending W always fills the
        # slot (memory stall or gap alike) unless the drain bank held it back
        if w_c is not None:
            return (t, (OpKind.W, w_c))
        if waits:
            return (min(waits), None)
        return (_INF, None)

    remaining = p * total
    guard = 0
    while remaining:
        guard += 1
        if guard > 100 * p * m + 10000:
            raise RuntimeError("v_flex greedy failed to converge")
        best_s, best_t, best_a = -1, _INF, None
        for s in range(p):
            if done[s] >= total:
                continue
            t, a = decide(s)
            if t < best_t or (t == best_t and a is not None and best_a is None):
                best_s, best_t, best_a = s, t, a
        if best_a is None:
            if best_t == _INF:
                stuck = {s: (nf[s], nb[s], nw[s]) for s in range(p)}
                raise RuntimeError(f"v_flex greedy deadlocked: {stuck}")
            clock[best_s] = best_t
            continue
        commit(best_s, best_a[0], best_a[1], max(best_t, clock[best_s]))
        remaining -= 1

    return Schedule(p, m, ops_out, placement=pl, name=name)


# --------------------------------------------------------------------- #
# W compaction: pull W passes earlier at equal simulated cost
# --------------------------------------------------------------------- #
def _wctx_backlog_peak(schedule: Schedule) -> int:
    worst = 0
    for ops in schedule.stage_ops:
        cur = 0
        for op in ops:
            if op.kind == OpKind.B:
                cur += 1
            elif op.kind == OpKind.W:
                cur -= 1
            worst = max(worst, cur)
    return worst


def _compact_w(
    schedule: Schedule,
    times,
    max_moves: int = 200,
    sim_budget: Optional[int] = None,
) -> Schedule:
    """Move W passes earlier while the simulated cost does not increase.

    Purely reduces the B->W context backlog (the W-context bytes a banked
    drain accumulates); activation peaks are untouched by W moves.

    Every attempted swap re-simulates the whole schedule, so the search is
    bounded: ``sim_budget`` caps the number of simulations (scaled down as
    schedules grow), and very large schedules skip compaction entirely --
    it is a cost-neutral backlog nicety, not worth minutes of build time
    at runtime-replanning scale (the portfolio is disk-cached, but the
    first build must still be interactive).
    """
    from ..simulator import simulate

    total_ops = sum(len(ops) for ops in schedule.stage_ops)
    if total_ops > 3000:
        return schedule
    if sim_budget is None:
        sim_budget = max(300, 120000 // max(1, total_ops))
    sims = 0

    best = schedule
    best_cost = simulate(best, times).cost
    moves = 0
    improved = True
    while improved and moves < max_moves:
        improved = False
        for s in range(best.p):
            ops = best.stage_ops[s]
            for i in range(1, len(ops)):
                if ops[i].kind != OpKind.W or ops[i - 1].kind == OpKind.W:
                    continue
                if sims >= sim_budget:
                    return best
                new_ops = [list(o) for o in best.stage_ops]
                new_ops[s][i - 1], new_ops[s][i] = new_ops[s][i], new_ops[s][i - 1]
                try:
                    cand = Schedule(
                        best.p, best.m, new_ops,
                        placement=best.placement, name=best.name,
                    )
                    sims += 1
                    cost = simulate(cand, times).cost
                except (ValueError, RuntimeError):
                    continue
                if cost <= best_cost + 1e-9 and (
                    _wctx_backlog_peak(cand) < _wctx_backlog_peak(best)
                    or cost < best_cost - 1e-9
                ):
                    best, best_cost = cand, min(best_cost, cost)
                    improved = True
                    moves += 1
                    break
            if improved:
                break
    return best


# --------------------------------------------------------------------- #
# public constructors
# --------------------------------------------------------------------- #
def v_flex(
    p: int,
    m: int,
    act_limit: float,
    times=None,
    name: str = "v-flex",
    compact: bool = True,
) -> Schedule:
    """Fastest V-placement schedule with peak activation <= act_limit (M_B).

    Simulates a deterministic portfolio: the stable-pattern construction
    plus greedy variants over {tapered, flat} warm-up/lead shapes,
    chunk-0 reserve {1, 2} and drain W-banking {on, off}; returns the
    feasible schedule with the lowest simulated cost (ties: smallest
    W-context backlog).

    Portfolio construction + simulation is memoized per
    ``(p, m, act_limit, times, compact)`` in an in-process LRU (planner
    budget sweeps and test grids rebuild the same few schedules dozens of
    times) backed by the content-keyed on-disk plan cache (cross-process
    sweeps, see repro.core.plan_cache); each call returns a fresh
    :class:`Schedule` built from the cached op lists, so callers may
    mutate ``name`` freely.
    """
    from ..simulator import TimeModel

    times = times or TimeModel.unit()
    ops, placement = _v_flex_build(p, m, float(act_limit), times, bool(compact))
    sched = Schedule(p, m, [list(o) for o in ops], placement=placement, name=name)
    return sched


@functools.lru_cache(maxsize=256)
def _v_flex_build(
    p: int, m: int, act_limit: float, times, compact: bool
) -> Tuple[Tuple[Tuple[Op, ...], ...], Placement]:
    """Memoized portfolio search; returns immutable (stage_ops, placement).

    Two cache layers: this in-process LRU, and underneath it the
    content-keyed on-disk plan cache (:mod:`repro.core.plan_cache`, keyed
    ``(p, m, act_limit, times, compact)``) so cross-process budget sweeps
    replay the portfolio instead of rebuilding it.
    """
    from .. import plan_cache

    cache = plan_cache.default_cache()
    cache_key = cache.key(
        "v_flex",
        p=p,
        m=m,
        act_limit=act_limit,
        times=plan_cache.times_payload(times),
        compact=compact,
    )
    payload = cache.get(cache_key)
    if payload is not None:
        sched = plan_cache.schedule_from_payload(payload)
        return (
            tuple(tuple(ops) for ops in sched.stage_ops),
            sched.placement,
        )
    best = _v_flex_portfolio(p, m, act_limit, times, compact)
    cache.put(cache_key, plan_cache.schedule_to_payload(best))
    return (
        tuple(tuple(ops) for ops in best.stage_ops),
        best.placement,
    )


def _v_flex_portfolio(
    p: int, m: int, act_limit: float, times, compact: bool
) -> Schedule:
    """Build + simulate the deterministic portfolio; returns the winner."""
    from ..simulator import simulate
    cap = int(2 * act_limit)  # chunk passes (2 per full-stage M_B)
    if cap < 2:
        raise ValueError(f"act_limit {act_limit} < 1 M_B cannot run a V chunk pair")

    candidates: List[Schedule] = []
    for kind in ("v-min", "v-half"):
        try:
            candidates.append(stable_v_schedule(p, m, kind))
        except ValueError:
            pass
    for taper in (True, False):
        for reserve in (1, 2):
            for bank in (True, False):
                vec = [
                    max(2, min(cap - reserve, 2 * p - 1 - 2 * s)) if taper
                    else cap - reserve
                    for s in range(p)
                ]
                try:
                    candidates.append(
                        _v_greedy(p, m, cap, vec, reserve=reserve, bank_w=bank)
                    )
                except RuntimeError:
                    continue

    best = None
    best_key = None
    for sched in candidates:
        if activation_peak(sched) > act_limit + 1e-9:
            continue
        try:
            cost = simulate(sched, times).cost
        except (ValueError, RuntimeError):
            continue
        key = (cost, _wctx_backlog_peak(sched))
        if best is None or key < best_key:
            best, best_key = sched, key
    if best is None:
        raise RuntimeError(
            f"no feasible V schedule (p={p}, m={m}, act_limit={act_limit})"
        )
    if compact:
        best = _compact_w(best, times)
    return best


def v_min_limit(p: int, m_b: float = 1.0) -> float:
    """V-Min activation budget: ceil(p*M_B/3) + 2*M_B."""
    return math.ceil(p * m_b / 3.0) + 2.0 * m_b


def v_half_limit(p: int, m_b: float = 1.0) -> float:
    """V-Half activation budget: ceil(p*M_B/2) + 2*M_B."""
    return math.ceil(p * m_b / 2.0) + 2.0 * m_b


def v_min(p: int, m: int, times=None) -> Schedule:
    """V-Min: ~1/3 of 1F1B activation memory (paper Sec. 4)."""
    return v_flex(p, m, v_min_limit(p), times, name="v-min")


def v_half(p: int, m: int, times=None) -> Schedule:
    """V-Half: ~1/2 of 1F1B activation memory, near-zero bubble."""
    return v_flex(p, m, v_half_limit(p), times, name="v-half")
