"""Schedule intermediate representation for zero-bubble pipeline parallelism.

A :class:`Schedule` is the paper's object of study: for each pipeline stage an
*ordered* list of passes, where each pass is one of

  * ``F``  -- forward of one microbatch through this stage's layer group,
  * ``B``  -- backward w.r.t. the *input* (activation gradient; carries the
              inter-stage dependency chain),
  * ``W``  -- backward w.r.t. the *parameters* (weight gradient; free to be
              scheduled any time after the matching ``B`` on the same stage).

Multi-chunk schedules (interleaved 1F1B, ZB-V) additionally tag each pass with
a chunk id; a :class:`Placement` describes which stage executes position ``k``
of chunk ``c`` in the forward direction.

The IR supports:
  * dependency validation (deadlock-freedom, completeness),
  * the paper's activation-memory profile (Sec. 2.3 / Appendix G deltas),
  * compilation to a static per-(stage, tick) table grid
    (:class:`ExecutionPlan`) consumed by the SPMD executor.
"""

from __future__ import annotations

import dataclasses
import enum
from typing import Dict, List, Optional, Sequence, Tuple

import numpy as np

__all__ = [
    "OpKind",
    "Op",
    "Placement",
    "Schedule",
    "MemoryProfile",
    "ExecutionPlan",
    "SteadyWindow",
    "CHANNEL_FWD_UP",
    "CHANNEL_FWD_DOWN",
    "CHANNEL_BWD_DOWN",
    "CHANNEL_BWD_UP",
]


class OpKind(enum.IntEnum):
    IDLE = 0
    F = 1
    B = 2
    W = 3


@dataclasses.dataclass(frozen=True, order=True)
class Op:
    """One pass in the pipeline: (kind, microbatch, chunk)."""

    kind: OpKind
    mb: int
    chunk: int = 0

    def __repr__(self) -> str:  # compact: F3.0 == forward mb 3 chunk 0
        return f"{self.kind.name}{self.mb}.{self.chunk}"


# Communication channels used by the tick executor. Each is a cyclic
# collective-permute over the pipe axis in the given direction carrying either
# activations (F) or activation gradients (B).
CHANNEL_FWD_UP = 0  # F output, stage s -> s+1
CHANNEL_FWD_DOWN = 1  # F output, stage s -> s-1   (ZB-V second chunk)
CHANNEL_BWD_DOWN = 2  # B output, stage s -> s-1
CHANNEL_BWD_UP = 3  # B output, stage s -> s+1   (ZB-V second chunk)
N_CHANNELS = 4


@dataclasses.dataclass(frozen=True)
class Placement:
    """Maps (chunk, position) -> stage.

    ``stage_seq[c][k]`` is the stage executing forward position ``k`` of chunk
    ``c``.  Every chunk visits every stage exactly once.  Examples for p=4:

      * single chunk:            ``[[0, 1, 2, 3]]``
      * interleaved, 2 chunks:   ``[[0, 1, 2, 3], [0, 1, 2, 3]]``
      * ZB-V:                    ``[[0, 1, 2, 3], [3, 2, 1, 0]]``
    """

    stage_seq: Tuple[Tuple[int, ...], ...]

    @property
    def p(self) -> int:
        return len(self.stage_seq[0])

    @property
    def n_chunks(self) -> int:
        return len(self.stage_seq)

    def __post_init__(self):
        p = self.p
        for c, seq in enumerate(self.stage_seq):
            if sorted(seq) != list(range(p)):
                raise ValueError(
                    f"chunk {c} placement {seq} must be a permutation of 0..{p-1}"
                )

    @staticmethod
    def linear(p: int, n_chunks: int = 1) -> "Placement":
        return Placement(tuple(tuple(range(p)) for _ in range(n_chunks)))

    @staticmethod
    def vshape(p: int) -> "Placement":
        return Placement((tuple(range(p)), tuple(reversed(range(p)))))

    def stage_of(self, chunk: int, pos: int) -> int:
        return self.stage_seq[chunk][pos]

    def pos_of(self, chunk: int, stage: int) -> int:
        return self.stage_seq[chunk].index(stage)

    def fwd_prev(self, chunk: int, pos: int) -> Optional[Tuple[int, int]]:
        """(chunk, pos) producing the input activation, or None for the source."""
        if pos > 0:
            return (chunk, pos - 1)
        if chunk > 0:
            return (chunk - 1, self.p - 1)
        return None

    def fwd_next(self, chunk: int, pos: int) -> Optional[Tuple[int, int]]:
        if pos < self.p - 1:
            return (chunk, pos + 1)
        if chunk < self.n_chunks - 1:
            return (chunk + 1, 0)
        return None


@dataclasses.dataclass
class MemoryProfile:
    """Peak activation memory per stage in units of (M_B, M_W).

    Deltas per the paper's Appendix G: F:+M_B, B:+M_W-M_B, W:-M_W.
    """

    peak: np.ndarray  # (p,) floats, in units given by m_b/m_w
    m_b: float
    m_w: float

    @property
    def max_peak(self) -> float:
        return float(self.peak.max())


class Schedule:
    """An ordered per-stage program of F/B/W passes."""

    def __init__(
        self,
        p: int,
        m: int,
        stage_ops: Sequence[Sequence[Op]],
        placement: Optional[Placement] = None,
        name: str = "custom",
    ):
        self.p = p
        self.m = m
        self.placement = placement or Placement.linear(p)
        self.stage_ops: List[List[Op]] = [list(ops) for ops in stage_ops]
        self.name = name
        if len(self.stage_ops) != p:
            raise ValueError(f"need {p} stage programs, got {len(self.stage_ops)}")
        if self.placement.p != p:
            raise ValueError("placement p mismatch")
        self._validate_completeness()

    # ------------------------------------------------------------------ #
    # validation
    # ------------------------------------------------------------------ #
    @property
    def n_chunks(self) -> int:
        return self.placement.n_chunks

    def _validate_completeness(self) -> None:
        """Each stage runs each (kind, mb, chunk) exactly once, W after B."""
        for s, ops in enumerate(self.stage_ops):
            seen = set()
            for op in ops:
                if op in seen:
                    raise ValueError(f"stage {s}: duplicate op {op}")
                seen.add(op)
            expected = {
                Op(kind, j, c)
                for kind in (OpKind.F, OpKind.B, OpKind.W)
                for j in range(self.m)
                for c in range(self.n_chunks)
            }
            if seen != expected:
                missing = sorted(expected - seen)[:4]
                extra = sorted(seen - expected)[:4]
                raise ValueError(
                    f"stage {s}: op set mismatch (missing {missing}..., extra {extra}...)"
                )
            # W strictly after matching B; B strictly after matching F.
            idx = {op: i for i, op in enumerate(ops)}
            for j in range(self.m):
                for c in range(self.n_chunks):
                    if not (
                        idx[Op(OpKind.F, j, c)]
                        < idx[Op(OpKind.B, j, c)]
                        < idx[Op(OpKind.W, j, c)]
                    ):
                        raise ValueError(
                            f"stage {s}: F<B<W order violated for mb={j} chunk={c}"
                        )

    def dependencies(self, stage: int, op: Op) -> List[Tuple[int, Op]]:
        """Cross-op dependencies (producer stage, producer op) of ``op``.

        Same-stage program order is an additional implicit dependency.
        """
        pl = self.placement
        deps: List[Tuple[int, Op]] = []
        pos = pl.pos_of(op.chunk, stage)
        if op.kind == OpKind.F:
            prev = pl.fwd_prev(op.chunk, pos)
            if prev is not None:
                pc, pp = prev
                deps.append((pl.stage_of(pc, pp), Op(OpKind.F, op.mb, pc)))
        elif op.kind == OpKind.B:
            nxt = pl.fwd_next(op.chunk, pos)
            if nxt is None:
                # loss position: B starts from the loss, right after local F.
                deps.append((stage, Op(OpKind.F, op.mb, op.chunk)))
            else:
                nc, np_ = nxt
                deps.append((pl.stage_of(nc, np_), Op(OpKind.B, op.mb, nc)))
                # B also needs this stage's own residuals:
                deps.append((stage, Op(OpKind.F, op.mb, op.chunk)))
        elif op.kind == OpKind.W:
            deps.append((stage, Op(OpKind.B, op.mb, op.chunk)))
        return deps

    def validate(self) -> None:
        """Raise if the schedule deadlocks (unsatisfiable dependency order)."""
        self.to_ticks()  # raises on deadlock

    # ------------------------------------------------------------------ #
    # memory profile (paper Sec 2.3)
    # ------------------------------------------------------------------ #
    def memory_profile(self, m_b: float = 1.0, m_w: float = 0.5) -> MemoryProfile:
        delta = {OpKind.F: m_b, OpKind.B: m_w - m_b, OpKind.W: -m_w}
        peak = np.zeros(self.p)
        for s, ops in enumerate(self.stage_ops):
            cur = 0.0
            for op in ops:
                cur += delta[op.kind]
                peak[s] = max(peak[s], cur)
        return MemoryProfile(peak=peak, m_b=m_b, m_w=m_w)

    def max_inflight(self) -> int:
        """Max concurrent (F issued, W not yet done) per stage -- buffer slots."""
        worst = 0
        for ops in self.stage_ops:
            cur = 0
            for op in ops:
                if op.kind == OpKind.F:
                    cur += 1
                elif op.kind == OpKind.W:
                    cur -= 1
                worst = max(worst, cur)
        return worst

    # ------------------------------------------------------------------ #
    # tick compilation
    # ------------------------------------------------------------------ #
    def to_ticks(self) -> Dict[Tuple[int, Op], int]:
        """Greedy list-scheduling under unit op durations.

        Each op occupies one tick on its stage; outputs cross stages at tick
        boundaries, so a dependent op runs no earlier than dep_tick + 1.
        Returns {(stage, op): tick}.  Raises ValueError on deadlock.
        """
        tick: Dict[Tuple[int, Op], int] = {}
        ptr = [0] * self.p  # next op index per stage
        clock = [0] * self.p  # next free tick per stage
        total = sum(len(ops) for ops in self.stage_ops)
        scheduled = 0
        while scheduled < total:
            progress = False
            for s in range(self.p):
                while ptr[s] < len(self.stage_ops[s]):
                    op = self.stage_ops[s][ptr[s]]
                    deps = self.dependencies(s, op)
                    ready = 0
                    ok = True
                    for ds, dop in deps:
                        key = (ds, dop)
                        if key not in tick:
                            ok = False
                            break
                        ready = max(ready, tick[key] + 1)
                    if not ok:
                        break
                    t = max(clock[s], ready)
                    tick[(s, op)] = t
                    clock[s] = t + 1
                    ptr[s] += 1
                    scheduled += 1
                    progress = True
            if not progress:
                stuck = {
                    s: self.stage_ops[s][ptr[s]]
                    for s in range(self.p)
                    if ptr[s] < len(self.stage_ops[s])
                }
                raise ValueError(f"schedule deadlock; next-ops: {stuck}")
        return tick

    def n_ticks(self) -> int:
        return max(self.to_ticks().values()) + 1

    def bubble_ticks(self) -> int:
        """Idle ticks summed over stages within the global [0, T) window."""
        t = self.to_ticks()
        total = (max(t.values()) + 1) * self.p
        return total - sum(len(ops) for ops in self.stage_ops)

    # ------------------------------------------------------------------ #
    # pretty printing
    # ------------------------------------------------------------------ #
    def render(self, max_width: int = 240) -> str:
        ticks = self.to_ticks()
        T = max(ticks.values()) + 1
        grid = [["." for _ in range(T)] for _ in range(self.p)]
        for (s, op), t in ticks.items():
            ch = {OpKind.F: "F", OpKind.B: "B", OpKind.W: "W"}[op.kind]
            if self.n_chunks > 1 and op.chunk > 0:
                ch = ch.lower()
            grid[s][t] = ch
        lines = [f"# {self.name} p={self.p} m={self.m} T={T}"]
        for s in range(self.p):
            lines.append("".join(grid[s])[:max_width])
        return "\n".join(lines)

    def __repr__(self) -> str:
        return (
            f"Schedule({self.name!r}, p={self.p}, m={self.m}, "
            f"chunks={self.n_chunks}, ops={sum(len(o) for o in self.stage_ops)})"
        )


# ---------------------------------------------------------------------- #
# slot allocation
# ---------------------------------------------------------------------- #
def _allocate_slots(
    intervals: Dict[Tuple, Tuple[int, int]],
) -> Tuple[Dict[Tuple, int], int]:
    """Greedy interval-graph slot assignment.

    intervals: key -> (alloc_tick, free_tick); the resource is live on
    [alloc_tick, free_tick] inclusive.  Returns (key -> slot, n_slots).
    """
    events = sorted(intervals.items(), key=lambda kv: (kv[1][0], kv[1][1]))
    free: List[int] = []
    n_slots = 0
    by_end: List[Tuple[int, int]] = []  # (free_tick, slot) of live entries
    out: Dict[Tuple, int] = {}
    for key, (start, end) in events:
        # release every slot freed strictly before this start
        still = []
        for ft, slot in by_end:
            if ft < start:
                free.append(slot)
            else:
                still.append((ft, slot))
        by_end = still
        if free:
            slot = min(free)
            free.remove(slot)
        else:
            slot = n_slots
            n_slots += 1
        out[key] = slot
        by_end.append((end, slot))
    return out, n_slots


@dataclasses.dataclass(frozen=True)
class SteadyWindow:
    """A structurally periodic region of an :class:`ExecutionPlan`.

    Ticks ``[start, start + period * repeats)`` repeat with ``period`` in
    every *structural* table (op kind/chunk, the src/loss/last-B flags and
    the send/recv channel pattern -- ``ExecutionPlan._STRUCT_TABLES``), so
    each tick of the period compiles to the same code: same branch
    dispatch, same collectives, same folded conditionals.  Index-valued
    tables (microbatch ids, buffer slots) may still differ between periods
    -- slot pools cycle with their own period -- and are fed to the scan
    superstep as per-period inputs instead.  The specialized executor
    unrolls warmup/cooldown and compiles the period once inside a
    ``lax.scan``, bounding trace size by ``start + period + (n_ticks -
    stop)`` instead of ``n_ticks``.
    """

    start: int
    period: int
    repeats: int

    @property
    def stop(self) -> int:
        return self.start + self.period * self.repeats

    def saved_ticks(self) -> int:
        """Ticks the scan superstep keeps out of the unrolled trace."""
        return (self.repeats - 1) * self.period


@dataclasses.dataclass
class ExecutionPlan:
    """Static per-(stage, tick) tables driving the SPMD tick executor.

    All arrays are numpy, converted to device constants by the executor.
    Semantics of one tick, for stage ``s`` at tick ``t``:

      1. compute ``op_kind[s, t]`` on chunk ``op_chunk`` / microbatch ``op_mb``
         reading input from inbox slot ``op_in_slot`` (or batch tokens when
         ``op_is_src``, or the loss seed when ``op_is_loss``), residuals from /
         to slot ``op_res_slot``;
      2. write the op output into channel ``send_channel[s, t]`` (or deposit
         locally into chunk ``local_chunk``/slot ``local_slot`` when
         ``send_local``);
      3. all four channels collectively permute;
      4. deposit arrivals: for each channel d with ``recv_valid[s, t, d]``,
         store into inbox of ``recv_chunk``/``recv_slot``.

    Receives indexed at tick t are arrivals of messages *sent* at tick t
    (available to ops at tick t+1).
    """

    p: int
    m: int
    n_chunks: int
    n_ticks: int
    placement: Placement
    name: str

    op_kind: np.ndarray  # (p, T) int32: OpKind
    op_chunk: np.ndarray  # (p, T)
    op_mb: np.ndarray  # (p, T)
    op_in_slot: np.ndarray  # (p, T) inbox slot consumed by F (act) / B (grad)
    op_res_slot: np.ndarray  # (p, T) residual slot (written by F, freed by B)
    op_wctx_slot: np.ndarray  # (p, T) weight-grad context slot (B -> W)
    op_res_slot_joint: np.ndarray  # (p, T) slot in the cross-chunk shared pool
    op_wctx_slot_joint: np.ndarray  # (p, T) slot in the cross-chunk shared pool
    op_is_src: np.ndarray  # (p, T) bool: F reads batch tokens / B or W at pos0 chunk0
    op_is_loss: np.ndarray  # (p, T) bool: F/B/W at the loss position
    op_is_last_b: np.ndarray  # (p, T) bool: B at pos0 of chunk0 (no dx send)
    op_sink_slot: np.ndarray  # (p, T) sink (head+loss) residual slot, [F..B]
    op_sink_wctx_slot: np.ndarray  # (p, T) sink W-context slot, [B..W]

    send_channel: np.ndarray  # (p, T) int32 in {-1, 0..3}
    send_local: np.ndarray  # (p, T) bool
    local_chunk: np.ndarray  # (p, T)
    local_slot: np.ndarray  # (p, T)
    local_is_grad: np.ndarray  # (p, T) bool

    recv_valid: np.ndarray  # (p, T, 4) bool
    recv_chunk: np.ndarray  # (p, T, 4)
    recv_slot: np.ndarray  # (p, T, 4)

    n_act_slots: Tuple[int, ...]  # per chunk
    n_grad_slots: Tuple[int, ...]
    n_res_slots: Tuple[int, ...]  # per chunk (heterogeneous-chunk fallback)
    n_wctx_slots: Tuple[int, ...]
    n_res_slots_joint: int  # cross-chunk shared pool (uniform chunks)
    n_wctx_slots_joint: int
    n_sink_slots: int
    n_sink_wctx_slots: int

    # per-tick live-slot counts, replayed from the interval analysis; the
    # measured-memory model (repro.core.memory.measured_timeline) weights
    # these by real buffer bytes.
    res_live: np.ndarray  # (C, p, T)
    wctx_live: np.ndarray  # (C, p, T)
    inbox_act_live: np.ndarray  # (C, p, T)
    inbox_grad_live: np.ndarray  # (C, p, T)
    sink_live: np.ndarray  # (p, T)
    sink_wctx_live: np.ndarray  # (p, T)

    @property
    def total_ops(self) -> int:
        return int((self.op_kind != int(OpKind.IDLE)).sum())

    @property
    def bubble_fraction(self) -> float:
        return 1.0 - self.total_ops / (self.p * self.n_ticks)

    def inbox_slot_total(self) -> int:
        """Total inbox slots the executor allocates (act + grad families).

        The inboxes are flat (C, max-slots) buffers -- a uniform stride for
        the flattened slot indexing in the tick body -- so the allocation
        is C * max(per-chunk slots) per family, not the per-chunk sum.
        Single source of truth for ``PipelineExecutor.buffer_bytes`` and
        the planner's model-fidelity inbox estimate.
        """
        return self.n_chunks * (max(self.n_act_slots) + max(self.n_grad_slots))

    def channel_live_ticks(self) -> np.ndarray:
        """(4,) number of ticks each channel carries at least one message."""
        live = np.zeros(N_CHANNELS, dtype=np.int64)
        for d in range(N_CHANNELS):
            live[d] = int(((self.send_channel == d).any(axis=0)).sum())
        return live

    def used_channels(self) -> Tuple[int, ...]:
        return tuple(
            d for d in range(N_CHANNELS) if (self.send_channel == d).any()
        )

    # ------------------------------------------------------------------ #
    # trace-time specialization metadata (consumed by the specialized
    # executor mode; see DESIGN.md Sec. 8)
    # ------------------------------------------------------------------ #
    _TICK_TABLES = (
        "op_kind",
        "op_chunk",
        "op_mb",
        "op_in_slot",
        "op_res_slot",
        "op_wctx_slot",
        "op_res_slot_joint",
        "op_wctx_slot_joint",
        "op_is_src",
        "op_is_loss",
        "op_is_last_b",
        "op_sink_slot",
        "op_sink_wctx_slot",
        "send_channel",
        "send_local",
        "local_chunk",
        "local_slot",
        "local_is_grad",
        "recv_valid",
        "recv_chunk",
        "recv_slot",
    )

    def tick_column(self, t: int) -> Dict[str, np.ndarray]:
        """All per-tick table columns at tick ``t`` as host-side constants.

        Shapes: ``(p,)`` for the per-op tables, ``(p, 4)`` for the recv
        tables.  This is the *entire* input of one executor tick besides the
        carried buffer state, so two ticks with equal columns (modulo a
        uniform ``op_mb`` shift) compile to the same code.
        """
        return {name: getattr(self, name)[:, t] for name in self._TICK_TABLES}

    def channel_liveness(self) -> np.ndarray:
        """(T, 4) bool: does any stage send a message on channel d at tick t?

        The channel-liveness contract: the specialized executor emits a
        ``ppermute`` for exactly the True entries of this table (one per
        live (tick, channel) pair), with the edge list of
        :meth:`channel_edges`; the generic executor closes every used
        channel every tick.  ``channel_live_ticks() ==
        channel_liveness().sum(0)`` by construction.
        """
        live = np.zeros((self.n_ticks, N_CHANNELS), bool)
        for d in range(N_CHANNELS):
            live[:, d] = (self.send_channel == d).any(axis=0)
        return live

    def channel_edges(self, t: int, channel: int) -> List[Tuple[int, int]]:
        """Exact (sender, receiver) ppermute pairs for one (tick, channel).

        Empty when the channel is idle at tick ``t``.  Receivers are the
        senders' ring neighbours in the channel's direction; stages outside
        the list neither contribute nor receive a payload.
        """
        shift = {
            CHANNEL_FWD_UP: +1,
            CHANNEL_FWD_DOWN: -1,
            CHANNEL_BWD_DOWN: -1,
            CHANNEL_BWD_UP: +1,
        }[channel]
        senders = np.nonzero(self.send_channel[:, t] == channel)[0]
        return [(int(s), int((s + shift) % self.p)) for s in senders]

    # tables that must repeat *exactly* for ticks to share compiled code:
    # they decide branch dispatch, conditional folding, and which
    # collectives are emitted.  Index-valued tables (op_mb, slots) may vary
    # between periods and are scanned over instead.
    _STRUCT_TABLES = (
        "op_kind",
        "op_chunk",
        "op_is_src",
        "op_is_loss",
        "op_is_last_b",
        "send_channel",
        "send_local",
        "local_is_grad",
        "recv_valid",
    )

    def steady_window(
        self, min_repeats: int = 2, max_period: Optional[int] = None
    ) -> Optional["SteadyWindow"]:
        """Detect the longest structurally periodic steady-state region.

        Column equality is required on ``_STRUCT_TABLES`` only (see
        :class:`SteadyWindow`).  Returns the window saving the most
        unrolled ticks, preferring shorter periods on ties; ``None`` when
        nothing repeats at least ``min_repeats`` times.
        """
        T = self.n_ticks
        min_repeats = max(2, min_repeats)
        if max_period is None:
            max_period = 8 * self.p + 16
        max_period = min(max_period, T // min_repeats)
        if max_period < 1:
            return None

        sigs = [
            tuple(
                np.ascontiguousarray(getattr(self, k)[:, t]).tobytes()
                for k in self._STRUCT_TABLES
            )
            for t in range(T)
        ]

        best: Optional[SteadyWindow] = None
        for k in range(1, max_period + 1):
            t = 0
            while t + k < T:
                if sigs[t] != sigs[t + k]:
                    t += 1
                    continue
                a = t
                while t + k < T and sigs[t] == sigs[t + k]:
                    t += 1
                run = t - a  # matching pairs: ticks [a, a + run + k) repeat
                n = (run + k) // k
                if n >= min_repeats:
                    saved = (n - 1) * k
                    if best is None or saved > best.saved_ticks():
                        best = SteadyWindow(start=a, period=k, repeats=n)
        return best


def compile_plan(schedule: Schedule) -> ExecutionPlan:
    """Compile a validated Schedule into an ExecutionPlan table grid."""
    pl = schedule.placement
    p, m, C = schedule.p, schedule.m, schedule.n_chunks
    ticks = schedule.to_ticks()
    T = max(ticks.values()) + 1

    def tick_of(stage: int, op: Op) -> int:
        return ticks[(stage, op)]

    shape = (p, T)
    op_kind = np.zeros(shape, np.int32)
    op_chunk = np.zeros(shape, np.int32)
    op_mb = np.zeros(shape, np.int32)
    op_in_slot = np.full(shape, -1, np.int32)
    op_res_slot = np.full(shape, -1, np.int32)
    op_wctx_slot = np.full(shape, -1, np.int32)
    op_res_slot_joint = np.full(shape, -1, np.int32)
    op_wctx_slot_joint = np.full(shape, -1, np.int32)
    op_sink_wctx_slot = np.zeros(shape, np.int32)
    op_is_src = np.zeros(shape, bool)
    op_is_loss = np.zeros(shape, bool)
    op_is_last_b = np.zeros(shape, bool)
    op_sink_slot = np.zeros(shape, np.int32)
    send_channel = np.full(shape, -1, np.int32)
    send_local = np.zeros(shape, bool)
    local_chunk = np.zeros(shape, np.int32)
    local_slot = np.zeros(shape, np.int32)
    local_is_grad = np.zeros(shape, bool)
    recv_valid = np.zeros((p, T, N_CHANNELS), bool)
    recv_chunk = np.zeros((p, T, N_CHANNELS), np.int32)
    recv_slot = np.zeros((p, T, N_CHANNELS), np.int32)

    # --- residual slots: per (stage, chunk), live [F tick, B tick] -- the
    # paper's accounting: B's true input-gradient VJP emits the compact M_W
    # context and the F->B residual is dead; wctx slots live [B tick, W tick]
    # and carry the byte-minimal cut of the backward (wgrad matmul operands,
    # folded cheap grads, and -- for split recurrences -- stacked per-step
    # scan contexts; DESIGN.md Sec. 7).  Slot *counts* here are structure-
    # agnostic interval colorings; slot *bytes* come from the executor's
    # eval_shape pass, so a stacked context is just a bigger slot, never a
    # different slot.  Slots are also allocated *jointly* across chunks per
    # stage: when the chunks' residual structures agree (the uniform-group
    # SPMD case) the executor shares one pool, so a stage holding chunk-0 and
    # chunk-1 residuals at different times does not pay for both peaks. ---- #
    res_slots: Dict[Tuple[int, int, int], int] = {}  # (stage, chunk, mb) -> slot
    wctx_slots: Dict[Tuple[int, int, int], int] = {}  # live [B tick, W tick]
    res_slots_joint: Dict[Tuple[int, int, int], int] = {}
    wctx_slots_joint: Dict[Tuple[int, int, int], int] = {}
    n_res_slots = [0] * C
    n_wctx_slots = [0] * C

    def _res_iv(s, c, j):
        return (
            tick_of(s, Op(OpKind.F, j, c)),
            tick_of(s, Op(OpKind.B, j, c)),
        )

    def _wctx_iv(s, c, j):
        return (
            tick_of(s, Op(OpKind.B, j, c)),
            tick_of(s, Op(OpKind.W, j, c)),
        )

    for c in range(C):
        worst_r = worst_w = 0
        for s in range(p):
            iv_r = {(s, c, j): _res_iv(s, c, j) for j in range(m)}
            iv_w = {(s, c, j): _wctx_iv(s, c, j) for j in range(m)}
            alloc_r, nr = _allocate_slots(iv_r)
            alloc_w, nw = _allocate_slots(iv_w)
            res_slots.update(alloc_r)
            wctx_slots.update(alloc_w)
            worst_r = max(worst_r, nr)
            worst_w = max(worst_w, nw)
        n_res_slots[c] = worst_r
        n_wctx_slots[c] = worst_w

    n_res_slots_joint = n_wctx_slots_joint = 0
    for s in range(p):
        iv_r = {(s, c, j): _res_iv(s, c, j) for c in range(C) for j in range(m)}
        iv_w = {(s, c, j): _wctx_iv(s, c, j) for c in range(C) for j in range(m)}
        alloc_r, nr = _allocate_slots(iv_r)
        alloc_w, nw = _allocate_slots(iv_w)
        res_slots_joint.update(alloc_r)
        wctx_slots_joint.update(alloc_w)
        n_res_slots_joint = max(n_res_slots_joint, nr)
        n_wctx_slots_joint = max(n_wctx_slots_joint, nw)

    # --- sink (head+loss) slots at the loss position of the last chunk:
    # residuals live [F tick, B tick], the sink W-context [B tick, W tick] -- #
    sink_slots: Dict[Tuple[int, int], int] = {}  # (stage, mb) -> slot
    sink_wctx_slots: Dict[Tuple[int, int], int] = {}
    c_last = C - 1
    loss_stage = pl.stage_of(c_last, p - 1)
    iv_sink = {
        (loss_stage, j): (
            tick_of(loss_stage, Op(OpKind.F, j, c_last)),
            tick_of(loss_stage, Op(OpKind.B, j, c_last)),
        )
        for j in range(m)
    }
    iv_sink_w = {
        (loss_stage, j): (
            tick_of(loss_stage, Op(OpKind.B, j, c_last)),
            tick_of(loss_stage, Op(OpKind.W, j, c_last)),
        )
        for j in range(m)
    }
    alloc_s, n_sink = _allocate_slots(iv_sink)
    sink_slots.update(alloc_s)
    n_sink_slots = max(1, n_sink)
    alloc_sw, n_sink_w = _allocate_slots(iv_sink_w)
    sink_wctx_slots.update(alloc_sw)
    n_sink_wctx_slots = max(1, n_sink_w)

    # --- inbox slots ------------------------------------------------------ #
    # activation inbox entry for F(c, pos k>0 or chunk>0): live from the tick
    # the producer runs (send happens end of that tick) until consumed.
    act_slots: Dict[Tuple[int, int, int], int] = {}
    grad_slots: Dict[Tuple[int, int, int], int] = {}
    n_act_slots = [0] * C
    n_grad_slots = [0] * C
    inbox_act_live = np.zeros((C, p, T), np.int32)
    inbox_grad_live = np.zeros((C, p, T), np.int32)
    for c in range(C):
        a_worst = g_worst = 0
        for s in range(p):
            pos = pl.pos_of(c, s)
            a_iv: Dict[Tuple, Tuple[int, int]] = {}
            g_iv: Dict[Tuple, Tuple[int, int]] = {}
            prev = pl.fwd_prev(c, pos)
            nxt = pl.fwd_next(c, pos)
            for j in range(m):
                if prev is not None:
                    ps = pl.stage_of(*prev)
                    a_iv[(s, c, j)] = (
                        tick_of(ps, Op(OpKind.F, j, prev[0])),
                        tick_of(s, Op(OpKind.F, j, c)),
                    )
                if nxt is not None:
                    ns = pl.stage_of(*nxt)
                    g_iv[(s, c, j)] = (
                        tick_of(ns, Op(OpKind.B, j, nxt[0])),
                        tick_of(s, Op(OpKind.B, j, c)),
                    )
            alloc_a, na = _allocate_slots(a_iv)
            alloc_g, ng = _allocate_slots(g_iv)
            act_slots.update(alloc_a)
            grad_slots.update(alloc_g)
            a_worst = max(a_worst, na)
            g_worst = max(g_worst, ng)
            for (s_, c_, _j), (a, b) in a_iv.items():
                inbox_act_live[c_, s_, a : b + 1] += 1
            for (s_, c_, _j), (a, b) in g_iv.items():
                inbox_grad_live[c_, s_, a : b + 1] += 1
        n_act_slots[c] = a_worst
        n_grad_slots[c] = g_worst

    # --- per-tick live-slot counts (the measured-memory timeline's time
    # axis: these ARE the executor's alloc/free semantics, replayed) -------- #
    res_live = np.zeros((C, p, T), np.int32)
    wctx_live = np.zeros((C, p, T), np.int32)
    sink_live = np.zeros((p, T), np.int32)
    sink_wctx_live = np.zeros((p, T), np.int32)
    for c in range(C):
        for s in range(p):
            for j in range(m):
                a, b = _res_iv(s, c, j)
                res_live[c, s, a : b + 1] += 1
                a, b = _wctx_iv(s, c, j)
                wctx_live[c, s, a : b + 1] += 1
    for (s_, j), (a, b) in iv_sink.items():
        sink_live[s_, a : b + 1] += 1
    for (s_, j), (a, b) in iv_sink_w.items():
        sink_wctx_live[s_, a : b + 1] += 1

    # --- fill per-op tables ------------------------------------------------ #
    for s in range(p):
        for op in schedule.stage_ops[s]:
            t = tick_of(s, op)
            c, j = op.chunk, op.mb
            pos = pl.pos_of(c, s)
            op_kind[s, t] = int(op.kind)
            op_chunk[s, t] = c
            op_mb[s, t] = j
            op_res_slot[s, t] = res_slots[(s, c, j)]
            op_res_slot_joint[s, t] = res_slots_joint[(s, c, j)]
            if op.kind in (OpKind.B, OpKind.W):
                op_wctx_slot[s, t] = wctx_slots[(s, c, j)]
                op_wctx_slot_joint[s, t] = wctx_slots_joint[(s, c, j)]
            if pl.fwd_next(c, pos) is None:
                op_is_loss[s, t] = True
                op_sink_slot[s, t] = sink_slots[(s, j)]
                op_sink_wctx_slot[s, t] = sink_wctx_slots[(s, j)]
            if pl.fwd_prev(c, pos) is None:
                op_is_src[s, t] = True
            if op.kind == OpKind.F:
                prev = pl.fwd_prev(c, pos)
                nxt = pl.fwd_next(c, pos)
                if prev is None:
                    op_is_src[s, t] = True
                else:
                    op_in_slot[s, t] = act_slots[(s, c, j)]
                if nxt is None:
                    op_is_loss[s, t] = True
                else:
                    nc, npos = nxt
                    ns = pl.stage_of(nc, npos)
                    dst_slot = act_slots[(ns, nc, j)]
                    if ns == s:
                        send_local[s, t] = True
                        local_chunk[s, t] = nc
                        local_slot[s, t] = dst_slot
                        local_is_grad[s, t] = False
                    else:
                        if ns == (s + 1) % p:
                            ch = CHANNEL_FWD_UP
                        elif ns == (s - 1) % p:
                            ch = CHANNEL_FWD_DOWN
                        else:
                            raise ValueError(
                                f"F send {s}->{ns} is not an adjacent permute"
                            )
                        send_channel[s, t] = ch
                        recv_valid[ns, t, ch] = True
                        recv_chunk[ns, t, ch] = nc
                        recv_slot[ns, t, ch] = dst_slot
            elif op.kind == OpKind.B:
                nxt = pl.fwd_next(c, pos)
                prev = pl.fwd_prev(c, pos)
                if nxt is None:
                    op_is_loss[s, t] = True  # seed dy from loss
                else:
                    op_in_slot[s, t] = grad_slots[(s, c, j)]
                if prev is None:
                    op_is_last_b[s, t] = True  # nothing upstream of embedding
                else:
                    pc, ppos = prev
                    ps = pl.stage_of(pc, ppos)
                    dst_slot = grad_slots[(ps, pc, j)]
                    if ps == s:
                        send_local[s, t] = True
                        local_chunk[s, t] = pc
                        local_slot[s, t] = dst_slot
                        local_is_grad[s, t] = True
                    else:
                        if ps == (s - 1) % p:
                            ch = CHANNEL_BWD_DOWN
                        elif ps == (s + 1) % p:
                            ch = CHANNEL_BWD_UP
                        else:
                            raise ValueError(
                                f"B send {s}->{ps} is not an adjacent permute"
                            )
                        send_channel[s, t] = ch
                        recv_valid[ps, t, ch] = True
                        recv_chunk[ps, t, ch] = pc
                        recv_slot[ps, t, ch] = dst_slot

    return ExecutionPlan(
        p=p,
        m=m,
        n_chunks=C,
        n_ticks=T,
        placement=pl,
        name=schedule.name,
        op_kind=op_kind,
        op_chunk=op_chunk,
        op_mb=op_mb,
        op_in_slot=op_in_slot,
        op_res_slot=op_res_slot,
        op_wctx_slot=op_wctx_slot,
        op_res_slot_joint=op_res_slot_joint,
        op_wctx_slot_joint=op_wctx_slot_joint,
        op_is_src=op_is_src,
        op_is_loss=op_is_loss,
        op_is_last_b=op_is_last_b,
        op_sink_slot=op_sink_slot,
        op_sink_wctx_slot=op_sink_wctx_slot,
        send_channel=send_channel,
        send_local=send_local,
        local_chunk=local_chunk,
        local_slot=local_slot,
        local_is_grad=local_is_grad,
        recv_valid=recv_valid,
        recv_chunk=recv_chunk,
        recv_slot=recv_slot,
        n_act_slots=tuple(max(1, n) for n in n_act_slots),
        n_grad_slots=tuple(max(1, n) for n in n_grad_slots),
        n_res_slots=tuple(max(1, n) for n in n_res_slots),
        n_wctx_slots=tuple(max(1, n) for n in n_wctx_slots),
        n_res_slots_joint=max(1, n_res_slots_joint),
        n_wctx_slots_joint=max(1, n_wctx_slots_joint),
        n_sink_slots=n_sink_slots,
        n_sink_wctx_slots=n_sink_wctx_slots,
        res_live=res_live,
        wctx_live=wctx_live,
        inbox_act_live=inbox_act_live,
        inbox_grad_live=inbox_grad_live,
        sink_live=sink_live,
        sink_wctx_live=sink_wctx_live,
    )
