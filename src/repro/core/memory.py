"""Time-resolved pipeline memory model and the byte-level budget planner.

Three layers (DESIGN.md Sec. 5):

1. :func:`memory_timeline` -- the simulator-backed refinement of
   ``Schedule.memory_profile``: instead of walking op *counts*, it runs the
   discrete-event simulator and tracks the live buffers per stage over
   simulated time, separately for

     * **activations** (the paper's M_B term): allocated when F starts,
       freed when the matching B ends;
     * **W-contexts** (M_W, the kept cotangents of a split backward):
       allocated when B starts, freed when the matching W ends.

   Peaks match the op-count profile when ops never overlap idle time, but
   the timeline also yields *when* the peak happens and the global
   (cross-stage) footprint at any instant.

2. :class:`ActivationByteModel` -- converts (M_B, M_W) units into device
   bytes for a concrete :class:`~repro.models.lm.ArchConfig` and run shape
   (microbatch, seq_len, pipeline layout).  Per-layer stored-activation
   bytes are derived from the block kinds (attention / MLP / MoE / recurrent)
   so the same schedule is costed differently for e.g. gemma2 (d_ff = 4x)
   and a recurrent arch.

3. :func:`measured_timeline` -- the *measured* counterpart of (1)+(2): reads
   the actual tick-executor buffer shapes (``PipelineExecutor.buffer_bytes``
   / ``state_shapes``) and replays the plan's interval analysis (the
   executor's real alloc/free semantics) into per-tick live bytes.  This is
   how the analytic model is cross-checked against reality
   (tests/test_measured_memory.py): the executor's statically allocated
   slot pools equal the peak of the measured timeline, because greedy
   interval coloring is optimal on interval graphs.

4. :class:`MemoryBudgetPlanner` -- compatibility adapter over the unified
   HBM-aware planning layer (:mod:`repro.core.planner`): given a config and
   a *per-device HBM* byte budget (parameters + ZeRO-1 optimizer state +
   channel/inbox/sink buffers + schedule memory), searches the whole
   schedule family {1F1B, interleaved 1F1B, ZB-H1, ZB-H2, ZB-V, V-Half,
   V-Min, memory-limited auto-search, v_flex portfolio} and returns the
   fastest plan whose itemized bytes fit, or an explicit infeasibility
   report with the minimum budget that would fit.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Optional, Tuple

import numpy as np

from .schedules.ir import Op, OpKind, Schedule
from .simulator import TimeModel, simulate

__all__ = [
    "MemoryTimeline",
    "memory_timeline",
    "ActivationByteModel",
    "CandidatePlan",
    "PlannerDecision",
    "MemoryBudgetPlanner",
    "MeasuredTimeline",
    "measured_timeline",
    "measured_unit_bytes",
    "default_xla_temp_bytes",
]


# --------------------------------------------------------------------- #
# 1. time-resolved memory
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class MemoryTimeline:
    """Per-stage piecewise-constant memory over simulated time.

    ``events[s]`` is a sorted list of (time, act, wctx) samples taken after
    every change; ``peak_*`` are per-stage maxima in M_B units.
    """

    p: int
    m_b: float
    m_w: float
    events: List[List[Tuple[float, float, float]]]
    peak_act: np.ndarray  # (p,)
    peak_wctx: np.ndarray  # (p,)
    peak_total: np.ndarray  # (p,)

    @property
    def max_peak_act(self) -> float:
        return float(self.peak_act.max())

    @property
    def max_peak_total(self) -> float:
        return float(self.peak_total.max())

    def global_footprint(self, t: float) -> float:
        """Sum of all stages' live memory at time t (bytes == units * m_b)."""
        total = 0.0
        for stage_events in self.events:
            live = 0.0
            for ts, act, wctx in stage_events:
                if ts > t:
                    break
                live = act + wctx
            total += live
        return total


def memory_timeline(
    schedule: Schedule,
    times: Optional[TimeModel] = None,
    m_b: float = 1.0,
    m_w: float = 0.5,
    tick_times: bool = False,
) -> MemoryTimeline:
    """Track live activation / W-context buffers over simulated time.

    Conservative edges: allocations happen at op *start*, frees at op *end*
    (an activation is still resident while its B runs; the W-context is
    resident while its W runs).

    ``tick_times=True`` replaces the event-driven clock with the tick grid
    the SPMD executor actually runs on (every pass occupies one tick) -- the
    timebase to use when cross-checking against measured executor buffers.
    """
    times = times or TimeModel.unit()
    if tick_times:
        ticks = schedule.to_ticks()
        start_of = {k: float(t) for k, t in ticks.items()}
        end_of = {k: float(t) + 1.0 for k, t in ticks.items()}
    else:
        res = simulate(schedule, times)
        start_of, end_of = res.start, res.end
    C = schedule.n_chunks
    mb_c, mw_c = m_b / C, m_w / C
    # Edge ordering at equal times: continuous time is conservative
    # (allocations land before frees -- overlapping ops), the tick grid is
    # the executor's semantics (a slot freed at tick t is rewritten by the
    # next tick's op, so frees land at the boundary first).
    ao, fo = (1, 0) if tick_times else (0, 1)

    p = schedule.p
    events: List[List[Tuple[float, float, float]]] = []
    peak_act = np.zeros(p)
    peak_wctx = np.zeros(p)
    peak_total = np.zeros(p)
    for s in range(p):
        deltas: List[Tuple[float, int, float, float]] = []  # (t, order, d_act, d_wctx)
        for op in schedule.stage_ops[s]:
            t0, t1 = start_of[(s, op)], end_of[(s, op)]
            if op.kind == OpKind.F:
                deltas.append((t0, ao, mb_c, 0.0))
            elif op.kind == OpKind.B:
                deltas.append((t0, ao, 0.0, mw_c))
                deltas.append((t1, fo, -mb_c, 0.0))
            else:
                deltas.append((t1, fo, 0.0, -mw_c))
        deltas.sort(key=lambda d: (d[0], d[1]))
        act = wctx = 0.0
        series: List[Tuple[float, float, float]] = []
        for t, _, da, dw in deltas:
            act += da
            wctx += dw
            series.append((t, act, wctx))
            peak_act[s] = max(peak_act[s], act)
            peak_wctx[s] = max(peak_wctx[s], wctx)
            peak_total[s] = max(peak_total[s], act + wctx)
        events.append(series)
    return MemoryTimeline(
        p=p,
        m_b=m_b,
        m_w=m_w,
        events=events,
        peak_act=peak_act,
        peak_wctx=peak_wctx,
        peak_total=peak_total,
    )


# --------------------------------------------------------------------- #
# 2. activation byte model
# --------------------------------------------------------------------- #
_DTYPE_BYTES = {"float32": 4, "bfloat16": 2, "float16": 2, "float64": 8}

# query-block size of models/modules.attention: sequences up to 2 * block
# take the dense path and store the per-head (s, s) probabilities; longer
# ones are q-block-chunked with remat and keep O(s * d) residuals.
_ATTN_CHUNK_BLOCK = 1024

# W-context / stored-activation ratios per kind bucket, calibrated against
# the measured tiny-config grid (tests/test_split_blocks.py::
# test_compact_context_shrinks_recurrent_blocks).  "compact" is the
# byte-minimal cut of core/passes.py (the default split); "frontier" the
# legacy whole-scan-in-B cut -- kept so the shrink plan() sees is itself a
# modeled quantity.
_WCTX_RATIO = {
    True: {"attn": 0.35, "mlp": 0.50, "rec": 0.30},
    False: {"attn": 0.65, "mlp": 0.75, "rec": 0.55},
}

_XLA_TEMP_TABLE = None


def _xla_temp_table():
    global _XLA_TEMP_TABLE
    if _XLA_TEMP_TABLE is None:
        import json
        import pathlib

        path = (
            pathlib.Path(__file__).resolve().parent.parent
            / "configs"
            / "xla_temp_calibration.json"
        )
        try:
            _XLA_TEMP_TABLE = json.loads(path.read_text())
        except (OSError, ValueError):
            _XLA_TEMP_TABLE = {}
    return _XLA_TEMP_TABLE


def default_xla_temp_bytes(
    arch_name: str,
    tokens: Optional[int] = None,
    m_b_bytes: Optional[float] = None,
) -> float:
    """Checked-in per-config XLA-temp calibration (ROADMAP open item 1).

    ``configs/xla_temp_calibration.json`` holds the ``launch/dryrun.py``
    train-grid output (``--calibration-out``): per arch, the compiled
    cell's temp footprint in excess of the modeled schedule bytes, plus
    the calibration shape (per-device ``tokens``, ``tp``, ``p``) and the
    cell's modeled ``m_b_bytes``.  The byte model loads it by default so
    ``plan()`` charges compiler scratch without the caller running a
    dryrun first.

    Scaling: temp is dominated by per-token activation-shaped buffers, so
    the value scales with the ratio of the *planned* M_B unit to the
    calibration cell's (``m_b_bytes``; covers tokens and widths, so
    ``reduced()`` tiny variants, which share the arch name, are priced
    proportionally) and with the token ratio -- but never *up*: the grid
    compiles on the CPU backend, which holds full program liveness, so
    the calibrated value is already a ceiling at the production-grid
    shape and extrapolating it upward (e.g. to tp=1) would swamp every
    budget with CPU-only inflation.  Unknown archs price 0 (the
    pre-calibration behavior).
    """
    rec = _xla_temp_table().get(arch_name)
    if rec is None:
        return 0.0
    if isinstance(rec, (int, float)):
        return float(rec)
    raw = float(rec.get("xla_temp_bytes") or 0.0)
    scale = 1.0
    cal_m_b = rec.get("m_b_bytes")
    if m_b_bytes and cal_m_b:
        scale = min(scale, float(m_b_bytes) / float(cal_m_b))
    cal_tokens = rec.get("tokens")
    if tokens and cal_tokens:
        scale = min(scale, float(tokens) / float(cal_tokens))
    return raw * scale


@dataclasses.dataclass(frozen=True)
class ActivationByteModel:
    """Bytes behind one (M_B, M_W) unit for a concrete config + run shape.

    ``m_b_bytes`` is the stored-activation footprint of one microbatch
    through one *full stage* (all its layers, all chunks); ``m_w_bytes`` the
    matching B->W context.  Derivation (DESIGN.md Sec. 5): per token each
    block kind stores

      * attention-like (attn/attn_local/mla): inputs + projections
        ~ (4*d_model + 2*kv) where kv = n_kv_heads * head_dim, plus the
        O(s^2) scores term ``n_heads * s`` per token when the sequence
        takes the dense path (s <= 2048; the chunked path remats it),
      * MLP-like (mlp/moe): input + hidden ~ (d_model + 2*d_ff')
        with d_ff' the *activated* expert width for MoE,
      * recurrent (slstm/mlstm/rglru/encdec): state + gates ~ 6*d_model;

    the W context is priced as a per-kind fraction of the stored
    activations (``_WCTX_RATIO``), calibrated against the measured
    executor buffers on the tiny grid.  ``from_config(compact=True)`` (the
    default) prices the byte-minimal context of the compact split --
    recurrent blocks ~0.30 of M_B vs ~0.55 under the legacy
    whole-scan-in-B frontier cut (``compact=False``), which is how
    ``plan()`` sees the smaller M_W of the recurrent B/W split.
    ``xla_temp_bytes`` defaults to the checked-in per-config calibration
    table (:func:`default_xla_temp_bytes`).
    """

    m_b_bytes: float
    m_w_bytes: float
    per_layer_act: float
    per_layer_wctx: float
    layers_per_stage: int
    tokens: int
    dtype_bytes: int
    # per-config XLA scratch fudge, calibrated from a dryrun's
    # compiled.memory_analysis() (see calibrate_from_dryrun); the unified
    # planner adds it to every candidate's HBM total.
    xla_temp_bytes: float = 0.0

    @staticmethod
    def from_config(
        cfg,
        microbatch: int,
        seq_len: int,
        p: int,
        n_chunks: int = 1,
        tp_size: int = 1,
        compact: bool = True,
    ) -> "ActivationByteModel":
        dtype_bytes = _DTYPE_BYTES.get(cfg.dtype, 4)
        ex = cfg.extras_dict()
        head_dim = cfg.head_dim or (cfg.d_model // cfg.n_heads)
        kv = cfg.n_kv_heads * head_dim
        d_ff_act = cfg.d_ff
        if "n_active_experts" in ex and "n_experts" in ex:
            d_ff_act = cfg.d_ff * ex["n_active_experts"]

        ratio = _WCTX_RATIO[bool(compact)]
        # O(s^2) attention term (ROADMAP): the dense path materializes the
        # (s, s) probability matrix per head in the saved residuals --
        # n_heads * s extra stored floats per token.  The q-block-chunked
        # path (models/modules.attention, s > 2 * block) remats inside the
        # block scan, so long sequences keep O(s * d) residuals and the
        # term vanishes exactly where it would have dominated.
        dense_attn = seq_len <= 2 * _ATTN_CHUNK_BLOCK
        attn_scores = cfg.n_heads * seq_len if dense_attn else 0.0
        act_per_kind = {}
        wctx_per_kind = {}
        for kinds in cfg.block_pattern:
            for kind in kinds:
                if kind.startswith("attn") or kind == "mla":
                    act_per_kind[kind] = 4 * cfg.d_model + 2 * kv + attn_scores
                    wctx_per_kind[kind] = ratio["attn"] * (
                        4 * cfg.d_model + 2 * kv
                    )
                elif kind in ("mlp", "moe"):
                    act_per_kind[kind] = cfg.d_model + 2 * d_ff_act
                    wctx_per_kind[kind] = ratio["mlp"] * act_per_kind[kind]
                else:  # recurrent / state-space / frontier kinds
                    act_per_kind[kind] = 6 * cfg.d_model
                    wctx_per_kind[kind] = ratio["rec"] * act_per_kind[kind]

        period = len(cfg.block_pattern)
        per_block_act = sum(
            act_per_kind[k] for kinds in cfg.block_pattern for k in kinds
        ) / period
        per_block_wctx = sum(
            wctx_per_kind[k] for kinds in cfg.block_pattern for k in kinds
        ) / period

        g = max(1, math.ceil(cfg.n_layers / (p * n_chunks))) * n_chunks
        tokens = microbatch * seq_len
        per_layer_act = per_block_act * tokens * dtype_bytes / max(1, tp_size)
        per_layer_wctx = per_block_wctx * tokens * dtype_bytes / max(1, tp_size)
        return ActivationByteModel(
            m_b_bytes=per_layer_act * g,
            m_w_bytes=per_layer_wctx * g,
            per_layer_act=per_layer_act,
            per_layer_wctx=per_layer_wctx,
            layers_per_stage=g,
            tokens=tokens,
            dtype_bytes=dtype_bytes,
            xla_temp_bytes=default_xla_temp_bytes(
                getattr(cfg, "name", ""),
                tokens=tokens,
                m_b_bytes=per_layer_act * g,
            ),
        )

    def timeline_bytes(self, tl: "MemoryTimeline") -> Tuple[float, float, float]:
        """(act_bytes, wctx_bytes, total_bytes) peaks of a unit timeline."""
        act = float(tl.peak_act.max()) * self.m_b_bytes
        wctx = float(tl.peak_wctx.max()) * self.m_w_bytes
        total = float(
            max(
                a * self.m_b_bytes + w * self.m_w_bytes
                for series in tl.events
                for _, a, w in series
            )
        )
        return act, wctx, total

    def schedule_bytes(
        self,
        schedule: Schedule,
        times: Optional[TimeModel] = None,
        tick_times: bool = False,
    ) -> Tuple[float, float, float]:
        """(act_bytes, wctx_bytes, total_bytes) peak per device."""
        return self.timeline_bytes(
            memory_timeline(
                schedule, times, m_b=1.0, m_w=1.0, tick_times=tick_times
            )
        )

    @staticmethod
    def from_measured(m_b_bytes: float, m_w_bytes: float) -> "ActivationByteModel":
        """Byte model calibrated from *measured* executor buffer bytes
        (:func:`measured_unit_bytes`) instead of the analytic per-kind table."""
        return ActivationByteModel(
            m_b_bytes=float(m_b_bytes),
            m_w_bytes=float(m_w_bytes),
            per_layer_act=float(m_b_bytes),
            per_layer_wctx=float(m_w_bytes),
            layers_per_stage=1,
            tokens=0,
            dtype_bytes=0,
        )

    def calibrate_from_dryrun(
        self,
        memory_analysis,
        schedule: Optional[Schedule] = None,
        times: Optional[TimeModel] = None,
        tick_times: bool = False,
    ) -> "ActivationByteModel":
        """Fold a dryrun's ``compiled.memory_analysis()`` into the model.

        The model prices the *schedule* buffers (residuals + W-contexts);
        XLA additionally holds compiler-managed scratch the analytic table
        cannot see.  Whatever the compiled temp footprint exceeds the
        modeled schedule bytes by becomes a per-config additive fudge
        (``xla_temp_bytes``) that the planner charges against the budget.

        ``memory_analysis`` may be the object ``compiled.memory_analysis()``
        returns or a dryrun result dict (``temp_size_in_bytes`` /
        ``bytes_per_device`` keys, see launch/dryrun.py).  With no
        ``schedule`` the whole temp footprint is taken as the fudge
        (maximally conservative).
        """
        temp = getattr(memory_analysis, "temp_size_in_bytes", None)
        if temp is None and isinstance(memory_analysis, dict):
            temp = (
                memory_analysis.get("temp_size_in_bytes")
                or memory_analysis.get("bytes_per_device")
            )
        if temp is None:
            return self
        modeled = 0.0
        if schedule is not None:
            modeled = self.schedule_bytes(schedule, times, tick_times)[2]
        return dataclasses.replace(
            self, xla_temp_bytes=max(0.0, float(temp) - modeled)
        )


# --------------------------------------------------------------------- #
# 3. measured executor memory
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class MeasuredTimeline:
    """Per-stage live executor-buffer bytes over ticks, from real shapes.

    ``act_bytes`` counts the F->B residual pools (the paper's M_B term,
    freed when B completes), ``wctx_bytes`` the B->W contexts (M_W),
    ``inbox_bytes`` the channel inboxes, ``sink_bytes`` the head+loss
    residuals + contexts at the loss stage.  ``alloc_*`` are the executor's
    static slot-pool allocations; per stage, peak(live) == alloc because the
    pools are sized by optimal interval coloring.
    """

    p: int
    n_ticks: int
    act_bytes: np.ndarray  # (p, T)
    wctx_bytes: np.ndarray  # (p, T)
    inbox_bytes: np.ndarray  # (p, T)
    sink_bytes: np.ndarray  # (p, T)
    alloc_act: float
    alloc_wctx: float
    alloc_inbox: float
    alloc_sink: float
    alloc_total: float
    res_slot_bytes: Tuple[float, ...]  # per chunk
    wctx_slot_bytes: Tuple[float, ...]

    @property
    def peak_act(self) -> np.ndarray:
        return self.act_bytes.max(axis=1)

    @property
    def peak_wctx(self) -> np.ndarray:
        return self.wctx_bytes.max(axis=1)

    @property
    def peak_total(self) -> np.ndarray:
        return (
            self.act_bytes + self.wctx_bytes + self.inbox_bytes + self.sink_bytes
        ).max(axis=1)

    @property
    def max_peak_act(self) -> float:
        return float(self.peak_act.max())

    @property
    def max_peak_wctx(self) -> float:
        return float(self.peak_wctx.max())

    def unit_bytes(self) -> Tuple[float, float]:
        """(m_b_bytes, m_w_bytes): one microbatch through one full stage."""
        return (
            float(sum(self.res_slot_bytes)),
            float(sum(self.wctx_slot_bytes)),
        )


def measured_unit_bytes(executor, stage_params, shared, side_all):
    """(m_b_bytes, m_w_bytes) measured from the executor's real buffers.

    One full-stage M_B unit = the residual bytes of one microbatch through
    every chunk of a stage (sum of per-chunk slot bytes); likewise M_W for
    the B->W context.  Use these to calibrate an :class:`ActivationByteModel`
    against the program instead of the analytic per-kind table.
    """
    bb = executor.buffer_bytes(stage_params, shared, side_all)
    return float(sum(bb["res_slot_bytes"])), float(sum(bb["wctx_slot_bytes"]))


def measured_timeline(
    executor, stage_params, shared, side_all
) -> MeasuredTimeline:
    """Replay the plan's interval analysis weighted by real buffer bytes.

    ``executor`` is a :class:`~repro.core.executor.PipelineExecutor`;
    ``stage_params``/``shared``/``side_all`` may be arrays or
    ``ShapeDtypeStruct`` pytrees (nothing is computed).  The per-tick live
    counts come from the compiled plan -- they ARE the executor's alloc/free
    semantics: a residual slot is live [F, B], a W-context slot [B, W] --
    and are weighted by the byte size of one slot of each pool.
    """
    plan = executor.plan
    bb = executor.buffer_bytes(stage_params, shared, side_all)
    p, T, C = plan.p, plan.n_ticks, plan.n_chunks

    act = np.zeros((p, T))
    wctx = np.zeros((p, T))
    for c in range(C):
        act += plan.res_live[c] * bb["res_slot_bytes"][c]
        wctx += plan.wctx_live[c] * bb["wctx_slot_bytes"][c]
    chan_bytes = executor.channel_message_bytes()
    inbox = (
        plan.inbox_act_live.sum(axis=0) + plan.inbox_grad_live.sum(axis=0)
    ) * chan_bytes
    sink_slot = bb["sink"] / max(1, plan.n_sink_slots)
    sink_wctx_slot = bb["sink_wctx"] / max(1, plan.n_sink_wctx_slots)
    sink = plan.sink_live * sink_slot + plan.sink_wctx_live * sink_wctx_slot
    return MeasuredTimeline(
        p=p,
        n_ticks=T,
        act_bytes=act,
        wctx_bytes=wctx,
        inbox_bytes=inbox.astype(float),
        sink_bytes=sink.astype(float),
        alloc_act=bb["res"],
        alloc_wctx=bb["wctx"],
        alloc_inbox=bb["inbox"],
        alloc_sink=bb["sink"] + bb["sink_wctx"],
        alloc_total=bb["total"],
        res_slot_bytes=bb["res_slot_bytes"],
        wctx_slot_bytes=bb["wctx_slot_bytes"],
    )


# --------------------------------------------------------------------- #
# 4. budget planner
# --------------------------------------------------------------------- #
@dataclasses.dataclass
class CandidatePlan:
    name: str
    schedule: Optional[Schedule]
    cost: float
    bubble_rate: float
    peak_act_units: float  # M_B units
    peak_wctx_units: float
    act_bytes: float
    wctx_bytes: float
    total_bytes: float
    feasible: bool
    note: str = ""


@dataclasses.dataclass
class PlannerDecision:
    budget_bytes: float
    feasible: bool
    chosen: Optional[CandidatePlan]
    candidates: List[CandidatePlan]
    min_required_bytes: float  # smallest candidate footprint

    def summary(self) -> str:
        if self.feasible:
            c = self.chosen
            return (
                f"budget {self.budget_bytes/2**20:.0f} MiB -> {c.name} "
                f"(cost {c.cost:.1f}, bubble {c.bubble_rate:.3f}, "
                f"{c.total_bytes/2**20:.0f} MiB)"
            )
        return (
            f"budget {self.budget_bytes/2**20:.0f} MiB infeasible; "
            f"cheapest plan needs {self.min_required_bytes/2**20:.0f} MiB"
        )


class MemoryBudgetPlanner:
    """Pick the fastest schedule whose per-device HBM footprint fits a budget.

    Compatibility adapter over the unified planning layer
    (:class:`repro.core.planner.HBMPlanner`): since the planner refactor the
    budget is a true per-device HBM budget -- parameters, ZeRO-1-sharded
    optimizer state, channel/inbox/sink buffers and the XLA-temp fudge are
    charged on top of the schedule's activation + W-context bytes.  The
    candidate family additionally includes the ``v_flex`` portfolio at the
    budget-implied limit.  ``CandidatePlan.total_bytes`` is the itemized
    HBM total; the full breakdown lives on the underlying
    :class:`~repro.core.planner.PipelinePlan` (``.hbm``).
    """

    def __init__(
        self,
        cfg,
        p: int,
        m: int,
        microbatch: int,
        seq_len: int,
        times: Optional[TimeModel] = None,
        tp_size: int = 1,
        dp_size: int = 1,
        measured: bool = False,
        xla_temp_bytes: Optional[float] = None,
        program_factory=None,
    ):
        from .planner import HBMPlanner

        self.cfg = cfg
        self.p = p
        self.m = m
        self.times = times or TimeModel.unit()
        self.hbm = HBMPlanner(
            cfg,
            p=p,
            m=m,
            microbatch=microbatch,
            seq_len=seq_len,
            times=self.times,
            tp_size=tp_size,
            dp_size=dp_size,
            measured=measured,
            xla_temp_bytes=xla_temp_bytes,
            program_factory=program_factory,
        )
        self.bytes_1c = self.hbm.bytes_1c
        self.bytes_2c = self.hbm.bytes_2c

    # ------------------------------------------------------------------ #
    def _to_candidate(self, pp) -> CandidatePlan:
        if pp.schedule is None:
            return CandidatePlan(
                pp.name, None, float("inf"), 1.0, float("inf"), float("inf"),
                float("inf"), float("inf"), float("inf"), False, note=pp.note,
            )
        bd = pp.breakdown
        m_b = pp.byte_model.m_b_bytes or 1.0
        m_w = pp.byte_model.m_w_bytes or 1.0
        return CandidatePlan(
            name=pp.name,
            schedule=pp.schedule,
            cost=pp.cost,
            bubble_rate=pp.bubble_rate,
            peak_act_units=bd.act / m_b,
            peak_wctx_units=bd.wctx / m_w,
            act_bytes=bd.act,
            wctx_bytes=bd.wctx,
            total_bytes=bd.total,
            feasible=pp.fits,
            note=pp.note,
        )

    def candidates(self, budget_bytes: Optional[float] = None) -> List[CandidatePlan]:
        """Evaluate the full family (cached), plus budget-tuned searches."""
        return [self._to_candidate(pp) for pp in self.hbm.candidates(budget_bytes)]

    def plan(self, budget_bytes: float) -> PlannerDecision:
        report = self.hbm.plan(budget_bytes)
        cands = [self._to_candidate(pp) for pp in report.plans]
        chosen = None
        if report.chosen is not None:
            chosen = next(c for c in cands if c.name == report.chosen.name)
        return PlannerDecision(
            budget_bytes=budget_bytes,
            feasible=report.feasible,
            chosen=chosen,
            candidates=cands,
            min_required_bytes=report.min_required_bytes,
        )
