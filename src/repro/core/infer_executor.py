"""F-only pipelined serving executor (prefill + decode).

Same ticked shard_map structure as the training executor, reduced to forward
passes: m request groups stream through the stages (fill-drain), each stage
threading its per-group caches.  Decode carries a (b, 1, h) token activation;
prefill carries the full (b, s, h) sequence and emits the caches.

The decode pipeline's bubble fraction is (pC-1)/(m+pC-1) -- pipeline
parallelism wants many concurrent request groups; the long_500k (m=1) cell
honestly shows PP is the wrong axis for single-stream decode (EXPERIMENTS.md).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from .schedules.ir import Placement

PyTree = Any

__all__ = ["InferProgram", "InferExecutor", "compile_infer_plan"]


@dataclasses.dataclass
class InferPlan:
    p: int
    m: int
    n_chunks: int
    n_ticks: int
    valid: np.ndarray  # (p, T) bool: run an F this tick
    chunk: np.ndarray  # (p, T)
    mb: np.ndarray  # (p, T)
    is_src: np.ndarray  # (p, T)
    is_sink: np.ndarray  # (p, T)
    send_up: np.ndarray  # (p, T) send output to stage+1
    send_down: np.ndarray  # (p, T)
    send_local: np.ndarray  # (p, T) deposit locally (chunk turn)
    local_chunk: np.ndarray
    recv_up: np.ndarray  # (p, T, 2): [valid, chunk] arriving from stage-1
    recv_down: np.ndarray


def compile_infer_plan(placement: Placement, m: int) -> InferPlan:
    """Fill-drain forward pipeline via greedy list scheduling.

    F(c, k, j) runs at the earliest tick after its producer F finished
    (cross-stage arrivals land at tick+1) with its stage free; steady-state
    cadence is C ticks per microbatch (each stage owns C chunk passes).
    """
    p, C = placement.p, placement.n_chunks
    ticks = {}
    stage_free = [0] * p
    for j in range(m):
        for c in range(C):
            for k in range(p):
                s = placement.stage_of(c, k)
                prev = placement.fwd_prev(c, k)
                ready = 0
                if prev is not None:
                    ps = placement.stage_of(*prev)
                    ready = ticks[(prev[0], prev[1], j)] + 1
                t = max(ready, stage_free[s])
                ticks[(c, k, j)] = t
                stage_free[s] = t + 1
    T = max(ticks.values()) + 1
    shape = (p, T)
    valid = np.zeros(shape, bool)
    chunk = np.zeros(shape, np.int32)
    mb = np.zeros(shape, np.int32)
    is_src = np.zeros(shape, bool)
    is_sink = np.zeros(shape, bool)
    send_up = np.zeros(shape, bool)
    send_down = np.zeros(shape, bool)
    send_local = np.zeros(shape, bool)
    local_chunk = np.zeros(shape, np.int32)
    recv_up = np.zeros((p, T, 2), np.int32)
    recv_down = np.zeros((p, T, 2), np.int32)
    for j in range(m):
        for c in range(C):
            for k in range(p):
                s = placement.stage_of(c, k)
                t = ticks[(c, k, j)]
                assert not valid[s, t], "fill-drain collision"
                valid[s, t] = True
                chunk[s, t] = c
                mb[s, t] = j
                nxt = placement.fwd_next(c, k)
                if placement.fwd_prev(c, k) is None:
                    is_src[s, t] = True
                if nxt is None:
                    is_sink[s, t] = True
                else:
                    ns = placement.stage_of(*nxt)
                    if ns == s:
                        send_local[s, t] = True
                        local_chunk[s, t] = nxt[0]
                    elif ns == (s + 1) % p:
                        send_up[s, t] = True
                        recv_up[ns, t] = (1, nxt[0])
                    elif ns == (s - 1) % p:
                        send_down[s, t] = True
                        recv_down[ns, t] = (1, nxt[0])
                    else:
                        raise ValueError("non-adjacent send")
    return InferPlan(
        p=p,
        m=m,
        n_chunks=C,
        n_ticks=T,
        valid=valid,
        chunk=chunk,
        mb=mb,
        is_src=is_src,
        is_sink=is_sink,
        send_up=send_up,
        send_down=send_down,
        send_local=send_local,
        local_chunk=local_chunk,
        recv_up=recv_up,
        recv_down=recv_down,
    )


@dataclasses.dataclass
class InferProgram:
    """chunk_fns[c](params_c, x, side_mb, cache_c_mb, pos) -> (y, cache);
    src(shared, side_mb) -> x; sink(shared, y, side_mb) -> logits."""

    chunk_fns: Sequence[Callable]
    src: Callable
    sink: Callable
    act_shape: Tuple[int, ...]
    act_dtype: Any
    out_shape: Tuple[int, ...]
    out_dtype: Any


class InferExecutor:
    def __init__(self, program: InferProgram, plan: InferPlan, pipe_axis: str):
        self.program = program
        self.plan = plan
        self.pipe_axis = pipe_axis

    def build_step_fn(self):
        """(stage_params, shared, side_all, caches, pos) ->
        (outputs (m, *out_shape), new caches).

        ``caches``: per chunk, pytree with leading (m,) microbatch axis --
        this stage's slice of each request group's cache.
        """
        prog, plan = self.program, self.plan
        C = plan.n_chunks

        def step_fn(stage_params, shared, side_all, caches, pos):
            sidx = jax.lax.axis_index(self.pipe_axis)

            def row(tab):
                return jnp.asarray(tab)[sidx]

            xs = dict(
                valid=row(plan.valid),
                chunk=row(plan.chunk),
                mb=row(plan.mb),
                is_src=row(plan.is_src),
                is_sink=row(plan.is_sink),
                send_up=row(plan.send_up),
                send_down=row(plan.send_down),
                send_local=row(plan.send_local),
                local_chunk=row(plan.local_chunk),
                recv_up=row(plan.recv_up),
                recv_down=row(plan.recv_down),
            )

            zero_act = jnp.zeros(prog.act_shape, prog.act_dtype)
            inbox = jnp.zeros((C,) + prog.act_shape, prog.act_dtype)
            outputs = jnp.zeros((plan.m,) + prog.out_shape, prog.out_dtype)

            def side_at(j):
                return jax.tree_util.tree_map(
                    lambda a: jax.lax.dynamic_index_in_dim(a, j, 0, False),
                    side_all,
                )

            def tick(state, t):
                inbox, caches, outputs = state
                side_mb = side_at(t["mb"])

                def run_chunk(c):
                    def body(args):
                        inbox, caches, outputs = args
                        x_in = inbox[c]

                        def from_src(_):
                            return prog.src(shared, side_mb).astype(prog.act_dtype)

                        x = jax.lax.cond(
                            t["is_src"], from_src, lambda _: x_in, None
                        )
                        cache_mb = jax.tree_util.tree_map(
                            lambda a: jax.lax.dynamic_index_in_dim(
                                a, t["mb"], 0, False
                            ),
                            caches[c],
                        )
                        y, new_cache = prog.chunk_fns[c](
                            stage_params[c], x, side_mb, cache_mb, pos
                        )
                        caches = list(caches)
                        caches[c] = jax.tree_util.tree_map(
                            lambda buf, v: jax.lax.dynamic_update_index_in_dim(
                                buf, v.astype(buf.dtype), t["mb"], 0
                            ),
                            caches[c],
                            new_cache,
                        )

                        def to_sink(outputs):
                            out = prog.sink(shared, y, side_mb)
                            return jax.lax.dynamic_update_index_in_dim(
                                outputs, out.astype(outputs.dtype), t["mb"], 0
                            )

                        outputs = jax.lax.cond(
                            t["is_sink"], to_sink, lambda o: o, outputs
                        )
                        return (inbox, tuple(caches), outputs), y.astype(
                            prog.act_dtype
                        )

                    return body

                def idle(args):
                    return args, zero_act

                branches = [idle] + [run_chunk(c) for c in range(C)]
                bidx = jnp.where(t["valid"], t["chunk"] + 1, 0)
                (inbox, caches, outputs), y = jax.lax.switch(
                    bidx, branches, (inbox, caches, outputs)
                )

                # local deposit (chunk turn on the same stage)
                old = jax.lax.dynamic_index_in_dim(inbox, t["local_chunk"], 0, False)
                dep = jnp.where(t["send_local"], y, old)
                inbox = jax.lax.dynamic_update_index_in_dim(
                    inbox, dep, t["local_chunk"], 0
                )

                # channel permutes (up and down)
                p_ = plan.p
                up = jax.lax.ppermute(
                    jnp.where(t["send_up"], y, zero_act),
                    self.pipe_axis,
                    [(i, (i + 1) % p_) for i in range(p_)],
                )
                down = jax.lax.ppermute(
                    jnp.where(t["send_down"], y, zero_act),
                    self.pipe_axis,
                    [(i, (i - 1) % p_) for i in range(p_)],
                )
                for got, rv in ((up, t["recv_up"]), (down, t["recv_down"])):
                    old = jax.lax.dynamic_index_in_dim(inbox, rv[1], 0, False)
                    dep = jnp.where(rv[0] > 0, got, old)
                    inbox = jax.lax.dynamic_update_index_in_dim(
                        inbox, dep, rv[1], 0
                    )
                return (inbox, caches, outputs), None

            state0 = (inbox, tuple(caches), outputs)
            (inbox, caches_f, outputs), _ = jax.lax.scan(
                tick, state0, xs, length=plan.n_ticks
            )
            return outputs, caches_f

        return step_fn
