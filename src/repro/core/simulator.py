"""Discrete-event simulator for pipeline schedules.

Continuous-time counterpart of :meth:`Schedule.to_ticks`: ops run in each
stage's program order; an op starts when the stage is free AND all cross-op
dependencies have completed (+ ``t_comm`` when the producer is a different
stage).  ``cost`` is the global makespan, and the paper's bubble rate
(Sec. 5.3) is ``(cost - m * (T_F + T_B + T_W)) / cost``.

Supports per-stage/per-chunk durations (straggler studies, embed/head
compensation) and the ``grouped_w`` convention used to model the 1F1B /
1F1B-interleaved baselines where B and W are a single fused backward (the
activation-gradient send happens only after the fused op finishes).
"""

from __future__ import annotations

import dataclasses
from typing import Callable, Dict, Optional, Tuple

import numpy as np

from .schedules.ir import Op, OpKind, Schedule

__all__ = ["TimeModel", "SimResult", "simulate", "bubble_rate"]


@dataclasses.dataclass(frozen=True)
class TimeModel:
    """Durations for one *full-stage* F/B/W pass plus the p2p latency.

    For multi-chunk schedules each chunk pass costs ``1/n_chunks`` of the
    full-stage value (chunks evenly split the per-stage layer group).
    ``stage_scale`` optionally multiplies every duration of a stage
    (straggler modelling).  ``grouped_w`` folds W into B (classic 1F1B).
    """

    t_f: float = 1.0
    t_b: float = 1.0
    t_w: float = 1.0
    t_comm: float = 0.0
    grouped_w: bool = False
    stage_scale: Optional[Tuple[float, ...]] = None

    def duration(self, stage: int, op: Op, n_chunks: int) -> float:
        if self.grouped_w:
            base = {
                OpKind.F: self.t_f,
                OpKind.B: self.t_b + self.t_w,
                OpKind.W: 0.0,
            }[op.kind]
        else:
            base = {OpKind.F: self.t_f, OpKind.B: self.t_b, OpKind.W: self.t_w}[
                op.kind
            ]
        base /= n_chunks
        if self.stage_scale is not None:
            base *= self.stage_scale[stage]
        return base

    @staticmethod
    def unit() -> "TimeModel":
        return TimeModel(1.0, 1.0, 1.0, 0.0)


@dataclasses.dataclass
class SimResult:
    cost: float  # max per-stage execution span (paper Sec. 5.3)
    makespan: float  # global wall-clock end
    stage_busy: np.ndarray  # (p,) total busy time
    stage_span: np.ndarray  # (p,) last_end - first_start
    start: Dict[Tuple[int, Op], float]
    end: Dict[Tuple[int, Op], float]
    m: int
    ideal: float  # m * (T_F + T_B + T_W), the bubble-free cost

    @property
    def bubble_rate(self) -> float:
        return (self.cost - self.ideal) / self.cost

    @property
    def bubble_size(self) -> float:
        return self.cost - self.ideal


def simulate(schedule: Schedule, times: TimeModel) -> SimResult:
    p, C = schedule.p, schedule.n_chunks
    start: Dict[Tuple[int, Op], float] = {}
    end: Dict[Tuple[int, Op], float] = {}
    ptr = [0] * p
    clock = [0.0] * p
    busy = np.zeros(p)
    first = np.full(p, np.inf)
    total = sum(len(ops) for ops in schedule.stage_ops)
    done = 0
    while done < total:
        progress = False
        for s in range(p):
            while ptr[s] < len(schedule.stage_ops[s]):
                op = schedule.stage_ops[s][ptr[s]]
                deps = schedule.dependencies(s, op)
                ready = 0.0
                ok = True
                for ds, dop in deps:
                    key = (ds, dop)
                    if key not in end:
                        ok = False
                        break
                    lat = times.t_comm if ds != s else 0.0
                    ready = max(ready, end[key] + lat)
                if not ok:
                    break
                t0 = max(clock[s], ready)
                dur = times.duration(s, op, C)
                start[(s, op)] = t0
                end[(s, op)] = t0 + dur
                clock[s] = t0 + dur
                busy[s] += dur
                first[s] = min(first[s], t0)
                ptr[s] += 1
                done += 1
                progress = True
        if not progress:
            stuck = {
                s: schedule.stage_ops[s][ptr[s]]
                for s in range(p)
                if ptr[s] < len(schedule.stage_ops[s])
            }
            raise ValueError(f"simulation deadlock; next-ops: {stuck}")
    makespan = max(end.values())
    spans = np.array(
        [
            max(
                (end[(s, op)] for op in schedule.stage_ops[s]),
                default=0.0,
            )
            - (first[s] if np.isfinite(first[s]) else 0.0)
            for s in range(p)
        ]
    )
    ideal = schedule.m * (times.t_f + times.t_b + times.t_w)
    return SimResult(
        cost=float(spans.max()),
        makespan=makespan,
        stage_busy=busy,
        stage_span=spans,
        start=start,
        end=end,
        m=schedule.m,
        ideal=ideal,
    )


def bubble_rate(schedule: Schedule, times: TimeModel) -> float:
    return simulate(schedule, times).bubble_rate
