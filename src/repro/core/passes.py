"""F/B/W split machinery -- the paper's enabling primitive (Sec. 1, Fig. 1).

Every pipeline-stage computation is an :class:`FBWModule` with three passes:

  * ``fwd(params, x, side)   -> (y, res)``       -- forward, saving residuals
  * ``bwd_x(params, res, dy, side) -> (dx, wctx)`` -- input gradient (B)
  * ``bwd_w(params, wctx, side)    -> grads``      -- parameter gradient (W)

``B`` carries the inter-stage dependency chain; ``W`` is free to be scheduled
any time after its ``B`` on the same stage -- exactly the degree of freedom
the zero-bubble schedules exploit.

:func:`auto_fbw` derives a split for *any* JAX function, with true
computational separation (not rematerialization):

  1. ``fwd`` runs ``jax.vjp`` once; the returned pullback closure is a pytree
     (``jax.tree_util.Partial``), so its residuals are extracted by
     ``tree_flatten`` and stored in pipeline buffers.  Leaves that are merely
     forwarded parameter / side-input tracers are detected by object identity
     and *not* stored -- they are re-injected from the stage's own
     params/side at B/W time (otherwise every in-flight microbatch would
     duplicate the stage weights).
  2. ``bwd_x`` rebuilds the pullback and returns only ``dx``: XLA dead-code
     eliminates the dW matmuls from the B pass.
  3. ``bwd_w`` rebuilds it again and returns only ``grads``: the dx chain is
     DCE'd from the W pass.

FLOPs therefore match the paper's Table 1 split (B and W each carry one of
the two backward matmuls per forward matmul).  The auto path keeps the full
residual set alive until W (M_W = M_B + |dy|); manual modules may override
``bwd_x``/``bwd_w`` with a leaner hand-split wctx (M_W < M_B, Table 1).
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

__all__ = ["FBWModule", "auto_fbw", "SequentialFBW", "loss_seed"]

PyTree = Any


class FBWModule:
    """Protocol + base class for split-backward modules."""

    #: set by subclasses / factories
    name: str = "fbw"

    def init(self, key: jax.Array) -> PyTree:
        raise NotImplementedError

    def fwd(self, params: PyTree, x: PyTree, side: PyTree) -> Tuple[PyTree, PyTree]:
        raise NotImplementedError

    def bwd_x(
        self, params: PyTree, res: PyTree, dy: PyTree, side: PyTree
    ) -> Tuple[PyTree, PyTree]:
        raise NotImplementedError

    def bwd_w(
        self, params: PyTree, res: PyTree, wctx: PyTree, side: PyTree
    ) -> PyTree:
        """Parameter gradients from residuals (held F->W) + the B pass's
        wctx (the paper's nabla_z extras; for auto modules just dy)."""
        raise NotImplementedError

    # convenience: fused backward for parity testing against jax.grad
    def bwd_full(self, params, res, dy, side):
        dx, wctx = self.bwd_x(params, res, dy, side)
        return dx, self.bwd_w(params, res, wctx, side)


# --------------------------------------------------------------------- #
# automatic split
# --------------------------------------------------------------------- #
_STORE, _PARAM, _SIDE = 0, 1, 2


class _AutoFBW(FBWModule):
    def __init__(
        self,
        f: Callable[[PyTree, PyTree, PyTree], PyTree],
        init_fn: Optional[Callable[[jax.Array], PyTree]] = None,
        name: str = "auto",
    ):
        self.f = f
        self._init_fn = init_fn
        self.name = name
        self._treedef = None
        self._spec: Optional[List[Tuple[int, int]]] = None

    def init(self, key):
        if self._init_fn is None:
            raise NotImplementedError(f"{self.name}: no init_fn provided")
        return self._init_fn(key)

    # -- forward ---------------------------------------------------------- #
    def fwd(self, params, x, side):
        y, pullback = jax.vjp(lambda p, xx: self.f(p, xx, side), params, x)
        leaves, treedef = jax.tree_util.tree_flatten(pullback)
        self._treedef = treedef
        by_id = {}
        for i, leaf in enumerate(jax.tree_util.tree_leaves(params)):
            by_id.setdefault(id(leaf), (_PARAM, i))
        for i, leaf in enumerate(jax.tree_util.tree_leaves(side)):
            by_id.setdefault(id(leaf), (_SIDE, i))
        spec: List[Tuple[int, int]] = []
        stored = []
        for leaf in leaves:
            hit = by_id.get(id(leaf))
            if hit is not None:
                spec.append(hit)
            else:
                spec.append((_STORE, len(stored)))
                stored.append(leaf)
        self._spec = spec
        return y, tuple(stored)

    def _rebuild(self, params, stored, side):
        if self._treedef is None or self._spec is None:
            raise RuntimeError(
                f"{self.name}: fwd must be traced before bwd (call "
                "ensure_traced or run fwd under jax.eval_shape first)"
            )
        p_leaves = jax.tree_util.tree_leaves(params)
        s_leaves = jax.tree_util.tree_leaves(side)
        leaves = []
        for kind, i in self._spec:
            if kind == _STORE:
                leaves.append(stored[i])
            elif kind == _PARAM:
                leaves.append(p_leaves[i])
            else:
                leaves.append(s_leaves[i])
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    # -- B: input gradient only (dW chain is DCE'd) ------------------------ #
    def bwd_x(self, params, res, dy, side):
        pullback = self._rebuild(params, res, side)
        _, dx = pullback(dy)
        return dx, dy  # wctx = the output cotangent only; res rides its buffer

    # -- W: parameter gradient only (dx chain is DCE'd) -------------------- #
    def bwd_w(self, params, res, wctx, side):
        dy = wctx
        pullback = self._rebuild(params, res, side)
        grads, _ = pullback(dy)
        return grads

    def ensure_traced(self, params, x, side) -> None:
        """Populate the static residual spec without running any compute."""
        jax.eval_shape(lambda p, xx, sd: self.fwd(p, xx, sd), params, x, side)


def auto_fbw(
    f: Callable[[PyTree, PyTree, PyTree], PyTree],
    init_fn: Optional[Callable[[jax.Array], PyTree]] = None,
    name: str = "auto",
) -> _AutoFBW:
    """Split any ``f(params, x, side) -> y`` into F/B/W passes."""
    return _AutoFBW(f, init_fn, name)


# --------------------------------------------------------------------- #
# sequential composition (a pipeline chunk = this stage's layer group)
# --------------------------------------------------------------------- #
class SequentialFBW(FBWModule):
    """Compose FBW modules; F runs left-to-right, B right-to-left.

    During B, each sub-module's dy is materialized and packed into the
    wctx -- these are exactly the paper's "extra gradients (nabla_z L) kept
    for W" (Table 1).
    """

    def __init__(self, modules: Sequence[FBWModule], name: str = "seq"):
        self.modules = list(modules)
        self.name = name

    def init(self, key):
        keys = jax.random.split(key, len(self.modules))
        return tuple(mod.init(k) for mod, k in zip(self.modules, keys))

    def fwd(self, params, x, side):
        res_all = []
        for mod, p in zip(self.modules, params):
            x, res = mod.fwd(p, x, side)
            res_all.append(res)
        return x, tuple(res_all)

    def bwd_x(self, params, res, dy, side):
        wctx_all: List[PyTree] = [None] * len(self.modules)
        for i in reversed(range(len(self.modules))):
            dy, wctx = self.modules[i].bwd_x(params[i], res[i], dy, side)
            wctx_all[i] = wctx
        return dy, tuple(wctx_all)

    def bwd_w(self, params, res, wctx, side):
        return tuple(
            mod.bwd_w(p, r, w, side)
            for mod, p, r, w in zip(self.modules, params, res, wctx)
        )

    def ensure_traced(self, params, x, side) -> None:
        jax.eval_shape(lambda p, xx, sd: self.fwd(p, xx, sd), params, x, side)


def loss_seed(loss: jax.Array) -> jax.Array:
    """Cotangent that seeds B at the loss position."""
    return jnp.ones_like(loss)
