"""F/B/W split machinery -- the paper's enabling primitive (Sec. 1, Fig. 1).

Every pipeline-stage computation is an :class:`FBWModule` with three passes:

  * ``fwd(params, x, side)   -> (y, res)``        -- forward, saving residuals
  * ``bwd_x(params, res, dy, side) -> (dx, wctx)`` -- input gradient (B)
  * ``bwd_w(params, wctx, side)    -> grads``      -- parameter gradient (W)

``B`` carries the inter-stage dependency chain; ``W`` is free to be scheduled
any time after its ``B`` on the same stage -- exactly the degree of freedom
the zero-bubble schedules exploit.

:func:`auto_fbw` derives a *true* split for any JAX function by partitioning
the backward jaxpr (no rematerialization, no pullback rebuild at W):

  1. ``fwd`` runs ``jax.vjp`` once; the pullback closure is a pytree
     (``jax.tree_util.Partial``), so its residuals are extracted by
     ``tree_flatten`` and stored in pipeline buffers.  Leaves that are merely
     forwarded parameter / side-input tracers are detected by object identity
     and *not* stored -- they are re-injected from the stage's own
     params/side at B/W time.
  2. On the first backward trace, the full pullback application
     ``(params, side, res, dy) -> (dparams, dx)`` is staged to a jaxpr,
     wrapper equations (``pjit`` / ``remat2`` / ``custom_vjp``) are inlined,
     and the flat program is partitioned: an equation belongs to the
     **B slice** iff its outputs are (transitively) needed for ``dx``; the
     equations needed for ``dparams`` form the **W slice**.  The values
     crossing the cut are the paper's ``M_W`` context.
  3. ``bwd_x`` evaluates only the B slice and returns ``(dx, wctx)`` where
     ``wctx`` is the tuple of cut values.  The F->B residuals are dead after
     this point: the executor frees their slot at B.
  4. ``bwd_w`` evaluates only the W slice from ``wctx`` plus re-injected
     params/side.  The wgrad GEMMs are never duplicated and the residuals
     are gone.

The context is not the naive B/W frontier: it is chosen *byte-minimal* by a
vertex min-cut over the backward dataflow (DESIGN.md Sec. 7).  Cheap
(elementwise / shape / reduction) equations may be replayed on the W side
from smaller stored precursors, and dparam cones made entirely of cheap ops
(mask grads, norm-gain grads, gate-scale grads) collapse to their finished
-- parameter-sized -- results computed at B.  GEMMs are pinned: a
``dot_general`` is never moved between slices, so the paper's Table-1 FLOP
split (B and W each carry one of the two backward matmuls per forward
matmul) is preserved exactly.  ``compact=False`` restores the frontier cut.

``scan`` equations are partitioned *recursively* (the recurrent B/W split):
a backward scan whose outputs feed both dx and dparams is split inside its
body.  B runs a dx-only scan that additionally emits a per-step compact
context as stacked outputs; W replays the dparam slice of the body as a
lightweight scan over that stacked context (dp-only accumulator carries --
e.g. a dW accumulated across steps -- move wholesale into the W scan).
Scans needed only for dparams run entirely in W with their unused inputs
pruned.  The recurrence's own residuals are therefore dead at B.

``bwd_w`` optionally takes a gradient accumulator; terminal ``dW = a^T @ g``
outer products are then routed through the fused accumulation kernel
(:func:`repro.kernels.ops.wgrad_accum`, paper App. A) when dtypes allow.
"""

from __future__ import annotations

import dataclasses
import os
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

try:  # jax >= 0.4.36 re-exports the core IR types here
    from jax.extend import core as _jcore
except ImportError:  # pragma: no cover
    import jax.core as _jcore

_Var = _jcore.Var
_Literal = _jcore.Literal
_DropVar = getattr(_jcore, "DropVar", None) or jax.core.DropVar

__all__ = ["FBWModule", "auto_fbw", "SequentialFBW", "loss_seed"]

PyTree = Any

#: default for auto_fbw(compact=...): byte-minimal W-contexts with cheap
#: replay + recursive scan split.  REPRO_SPLIT_COMPAT=1 restores the legacy
#: frontier cut globally (escape hatch; also the baseline tests measure
#: against).
_COMPACT_DEFAULT = os.environ.get("REPRO_SPLIT_COMPAT", "0") not in (
    "1",
    "on",
    "true",
)


class FBWModule:
    """Protocol + base class for split-backward modules."""

    #: set by subclasses / factories
    name: str = "fbw"

    def init(self, key: jax.Array) -> PyTree:
        raise NotImplementedError

    def fwd(self, params: PyTree, x: PyTree, side: PyTree) -> Tuple[PyTree, PyTree]:
        raise NotImplementedError

    def bwd_x(
        self, params: PyTree, res: PyTree, dy: PyTree, side: PyTree
    ) -> Tuple[PyTree, PyTree]:
        raise NotImplementedError

    def bwd_w(
        self, params: PyTree, wctx: PyTree, side: PyTree, acc: Optional[PyTree] = None
    ) -> PyTree:
        """Parameter gradients from the B pass's wctx alone (the paper's
        M_W context).  The F->B residuals are *not* available: they are
        freed when B completes.  When ``acc`` (a pytree matching params) is
        given, returns ``acc + grads`` with terminal outer products fused
        through the wgrad-accumulation kernel where dtypes allow."""
        raise NotImplementedError

    # convenience: fused backward for parity testing against jax.grad
    def bwd_full(self, params, res, dy, side):
        dx, wctx = self.bwd_x(params, res, dy, side)
        return dx, self.bwd_w(params, wctx, side)


# --------------------------------------------------------------------- #
# automatic split
# --------------------------------------------------------------------- #
_STORE, _PARAM, _SIDE = 0, 1, 2


@dataclasses.dataclass
class _SplitPlan:
    """Static partition of one backward jaxpr into B / W slices."""

    jaxpr: Any  # jax core Jaxpr
    consts: List[Any]
    b_eqns: List[int]
    w_eqns: List[int]
    cut_vars: List[Any]  # values riding the M_W context, in capture order
    reinject: Dict[Any, int]  # var -> flat (params+side) leaf index
    dp_vars: List[Any]
    dx_vars: List[Any]
    dp_tree: Any
    dx_tree: Any
    n_p: int
    n_s: int
    # dp leaf -> ("fuse", a_var, g_var, {eqn ids to skip}) | None
    wgrad_routes: List[Optional[Tuple]]
    key: Tuple


def _avals_key(*trees):
    return tuple(
        (tuple(l.shape), jnp.result_type(l).name)
        for l in jax.tree_util.tree_leaves(trees)
    )


def _eval_eqns(jaxpr, eqn_ids, env, skip=()):
    for i in eqn_ids:
        if i in skip:
            continue
        eqn = jaxpr.eqns[i]
        invals = [
            v.val if isinstance(v, _Literal) else env[v] for v in eqn.invars
        ]
        if isinstance(eqn, _SynthScanEqn):
            outs = eqn.run(invals)
        else:
            ans = eqn.primitive.bind(*invals, **eqn.params)
            outs = ans if eqn.primitive.multiple_results else [ans]
        for var, val in zip(eqn.outvars, outs):
            if not isinstance(var, _DropVar):
                env[var] = val


def _read(v, env):
    return jnp.asarray(v.val) if isinstance(v, _Literal) else env[v]


def _find_wgrad_routes(jaxpr, w_eqns, dp_vars):
    """Terminal ``dW = a^T @ g`` patterns eligible for fused accumulation.

    Matches a dp output produced (within the W slice) by either
    ``dot_general(u, v)`` contracting dim 0 with dim 0 (dW = u^T v), or the
    same followed by a rank-2 ``transpose`` (dW = v^T u).  The matched
    equations can then be *replaced* by one `wgrad_accum` call.
    """
    producer = {}
    use_count: Dict[Any, int] = {}
    w_set = set(w_eqns)
    for i, eqn in enumerate(jaxpr.eqns):
        for ov in eqn.outvars:
            if not isinstance(ov, _DropVar):
                producer[ov] = i
        for v in eqn.invars:
            if isinstance(v, _Var):
                use_count[v] = use_count.get(v, 0) + 1
    for v in jaxpr.outvars:
        if isinstance(v, _Var):
            use_count[v] = use_count.get(v, 0) + 1

    def _is_wgrad_dot(eqn):
        # dW = a^T @ g with the token dims flattened: contract every leading
        # dim of both rank-k operands (k >= 2), no batch dims.
        if eqn.primitive.name != "dot_general":
            return False
        (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
        if lb or rb:
            return False
        if not all(
            isinstance(v, _Var) and len(v.aval.shape) >= 2 for v in eqn.invars
        ):
            return False
        k = len(eqn.invars[0].aval.shape)
        lead = tuple(range(k - 1))
        return (
            len(eqn.invars[1].aval.shape) == k
            and tuple(lc) == lead
            and tuple(rc) == lead
        )

    routes = []
    for dp in dp_vars:
        route = None
        i = producer.get(dp)
        if i is not None and i in w_set and use_count.get(dp, 0) == 1:
            eqn = jaxpr.eqns[i]
            if _is_wgrad_dot(eqn):
                u, v = eqn.invars
                route = ("fuse", u, v, frozenset([i]))
            elif (
                eqn.primitive.name == "transpose"
                and tuple(eqn.params["permutation"]) == (1, 0)
                and isinstance(eqn.invars[0], _Var)
                and use_count.get(eqn.invars[0], 0) == 1
            ):
                j = producer.get(eqn.invars[0])
                if j is not None and j in w_set and _is_wgrad_dot(jaxpr.eqns[j]):
                    u, v = jaxpr.eqns[j].invars
                    route = ("fuse", v, u, frozenset([i, j]))
        routes.append(route)
    return routes


# --------------------------------------------------------------------- #
# flat backward IR: wrapper inlining + synthetic (split) scan equations
# --------------------------------------------------------------------- #
#: primitives cheap enough to re-evaluate on the W side (elementwise, shape,
#: reductions -- all O(bytes touched)); anything outside this set is pinned
#: to the slice the base partition put it in.  GEMMs / scans / collectives
#: are deliberately absent: B and W each keep exactly one backward matmul
#: per forward matmul (paper Table 1) and collectives fire once per slice.
_REPLAYABLE = frozenset(
    {
        "abs", "acos", "acosh", "add", "add_any", "and", "asin", "asinh",
        "atan", "atan2", "atanh", "bitcast_convert_type", "broadcast_in_dim",
        "cbrt", "ceil", "clamp", "concatenate", "convert_element_type",
        "copy", "cos", "cosh", "cumlogsumexp", "cummax", "cummin", "cumprod",
        "cumsum", "div", "dynamic_slice", "dynamic_update_slice", "eq",
        "erf", "erf_inv", "erfc", "exp", "exp2", "expm1", "floor", "ge",
        "gt", "imag", "integer_pow", "iota", "is_finite", "le", "log",
        "log1p", "logistic", "lt", "max", "min", "mul", "ne", "neg",
        "nextafter", "not", "or", "pad", "pow", "real", "reduce_and",
        "reduce_max", "reduce_min", "reduce_or", "reduce_prod", "reduce_sum",
        "rem", "reshape", "rev", "round", "rsqrt", "select_n", "shift_left",
        "shift_right_arithmetic", "shift_right_logical", "sign", "sin",
        "sinh", "slice", "split", "sqrt", "squeeze", "sub", "tan", "tanh",
        "transpose", "xor",
    }
)

#: wrapper primitives whose body jaxpr is inlined before partitioning, so
#: the cut can recurse into remat'd / custom-vjp'd / jitted sub-programs
_WRAPPER_PRIMS = (
    "pjit", "remat2", "checkpoint", "custom_jvp_call",
    "custom_jvp_call_jaxpr", "custom_vjp_call", "custom_vjp_call_jaxpr",
    "closed_call", "core_call",
)

_BIG = 1 << 62  # "infinite" capacity / not storable

_var_counter = [0]


def _fresh_var(aval):
    _var_counter[0] += 1
    try:
        return _jcore.Var("", aval)
    except TypeError:  # pragma: no cover - ctor signature drift across jax
        try:
            return _jcore.Var(aval)
        except TypeError:
            return jax.core.Var(_var_counter[0], "", aval)


@dataclasses.dataclass
class _FlatIR:
    """A flattened jaxpr stand-in (post wrapper inlining, scan rewriting).

    Quacks like a Jaxpr for everything the partitioner and the slice
    evaluators touch: ``constvars`` / ``invars`` / ``outvars`` / ``eqns``.
    """

    constvars: List[Any]
    invars: List[Any]
    outvars: List[Any]
    eqns: List[Any]


class _SynthPrim:
    multiple_results = True

    def __init__(self, name: str):
        self.name = name


class _SynthScanEqn:
    """One half of a split scan: evaluated via ``run`` instead of bind.

    ``run(invals)`` returns one value per outvar.  Exposes ``invars`` /
    ``outvars`` / ``primitive`` / ``params`` so the partition walks treat it
    like any other (non-replayable) equation.
    """

    def __init__(self, name, invars, outvars, run):
        self.primitive = _SynthPrim(name)
        self.invars = list(invars)
        self.outvars = list(outvars)
        self.params: Dict[str, Any] = {}
        self.run = run


def _eqn_replace(eqn, invars=None, outvars=None):
    kw = {}
    if invars is not None:
        kw["invars"] = invars
    if outvars is not None:
        kw["outvars"] = outvars
    return eqn.replace(**kw)


def _clone_body(jaxpr):
    """Fresh-var copy of a body jaxpr (vars only; primitives are shared)."""
    m: Dict[Any, Any] = {}

    def mv(v):
        if isinstance(v, _Literal) or isinstance(v, _DropVar):
            return v
        if not isinstance(v, _Var):
            return v
        if v not in m:
            m[v] = _fresh_var(v.aval)
        return m[v]

    eqns = [
        _eqn_replace(
            e,
            invars=[mv(v) for v in e.invars],
            outvars=[mv(v) for v in e.outvars],
        )
        for e in jaxpr.eqns
    ]
    return (
        [mv(v) for v in jaxpr.constvars],
        [mv(v) for v in jaxpr.invars],
        [mv(v) for v in jaxpr.outvars],
        eqns,
    )


def _wrapper_body(eqn):
    """(body_jaxpr, body_consts) for an inlinable wrapper eqn, else None."""
    name = getattr(eqn.primitive, "name", "")
    if name not in _WRAPPER_PRIMS:
        return None
    params = eqn.params
    cand = (
        params.get("jaxpr")
        or params.get("call_jaxpr")
        or params.get("fun_jaxpr")
    )
    if cand is None:
        return None
    if hasattr(cand, "jaxpr"):  # ClosedJaxpr
        return cand.jaxpr, list(cand.consts)
    return cand, []


def _inline_wrappers(jaxpr, consts) -> Tuple[_FlatIR, List[Any]]:
    """Flatten pjit / remat / custom-vjp wrappers into one equation list."""
    constvars = list(jaxpr.constvars)
    new_consts = list(consts)
    rename: Dict[Any, Any] = {}

    def res(v):
        while isinstance(v, _Var) and not isinstance(v, _DropVar) and v in rename:
            v = rename[v]
        return v

    out_eqns: List[Any] = []

    def emit(eqn, depth):
        eqn = _eqn_replace(eqn, invars=[res(v) for v in eqn.invars])
        body = _wrapper_body(eqn) if depth < 16 else None
        if body is None:
            out_eqns.append(eqn)
            return
        bjaxpr, bconsts = body
        cvs, ivs, ovs, beqns = _clone_body(bjaxpr)
        constvars.extend(cvs)
        new_consts.extend(bconsts)
        for bi, outer in zip(ivs, eqn.invars):
            rename[bi] = outer
        for be in beqns:
            emit(be, depth + 1)
        for bo, oo in zip(ovs, eqn.outvars):
            if isinstance(oo, _DropVar):
                continue
            rename[oo] = res(bo)

    for e in jaxpr.eqns:
        emit(e, 0)
    outvars = [res(v) for v in jaxpr.outvars]
    return _FlatIR(constvars, list(jaxpr.invars), outvars, out_eqns), new_consts


def _aval_bytes(v) -> int:
    aval = v.aval
    n = 1
    for d in aval.shape:
        n *= int(d)
    return max(1, n * jnp.dtype(aval.dtype).itemsize)


def _needed_vars(eqns, targets):
    """Vars transitively needed to compute ``targets`` (backward slice)."""
    need = set(v for v in targets if isinstance(v, _Var))
    for eqn in reversed(eqns):
        if any(ov in need for ov in eqn.outvars):
            need.update(v for v in eqn.invars if isinstance(v, _Var))
    return need


def _slice_eqns(eqns, targets, stop):
    """Equation ids needed for ``targets``, not walking past ``stop`` vars."""
    need = set(v for v in targets if isinstance(v, _Var) and v not in stop)
    ids: List[int] = []
    for i in range(len(eqns) - 1, -1, -1):
        eqn = eqns[i]
        if any(ov in need for ov in eqn.outvars):
            ids.append(i)
            need.update(
                v
                for v in eqn.invars
                if isinstance(v, _Var) and v not in stop
            )
    ids.reverse()
    return ids, need


class _Dinic:
    def __init__(self, n):
        self.n = n
        self.head: List[List[int]] = [[] for _ in range(n)]
        self.to: List[int] = []
        self.cap: List[int] = []

    def edge(self, u, v, c):
        self.head[u].append(len(self.to))
        self.to.append(v)
        self.cap.append(c)
        self.head[v].append(len(self.to))
        self.to.append(u)
        self.cap.append(0)

    def _bfs(self, s, t):
        self.level = [-1] * self.n
        self.level[s] = 0
        q = [s]
        for u in q:
            for ei in self.head[u]:
                v = self.to[ei]
                if self.cap[ei] > 0 and self.level[v] < 0:
                    self.level[v] = self.level[u] + 1
                    q.append(v)
        return self.level[t] >= 0

    def _augment(self, s, t):
        """One blocking-path augmentation (iterative DFS)."""
        path: List[int] = []  # edge ids along the current path
        u = s
        while True:
            if u == t:
                f = min(self.cap[ei] for ei in path)
                for ei in path:
                    self.cap[ei] -= f
                    self.cap[ei ^ 1] += f
                return f
            advanced = False
            while self.it[u] < len(self.head[u]):
                ei = self.head[u][self.it[u]]
                v = self.to[ei]
                if self.cap[ei] > 0 and self.level[v] == self.level[u] + 1:
                    path.append(ei)
                    u = v
                    advanced = True
                    break
                self.it[u] += 1
            if advanced:
                continue
            self.level[u] = -1  # dead end
            if not path:
                return 0
            u = self.to[path.pop() ^ 1]
            self.it[u] += 1

    def max_flow(self, s, t):
        flow = 0
        while self._bfs(s, t):
            self.it = [0] * self.n
            while True:
                f = self._augment(s, t)
                if f == 0:
                    break
                flow += f
        return flow

    def s_side(self, s):
        seen = [False] * self.n
        seen[s] = True
        q = [s]
        for u in q:
            for ei in self.head[u]:
                v = self.to[ei]
                if self.cap[ei] > 0 and not seen[v]:
                    seen[v] = True
                    q.append(v)
        return seen


def _byte_min_cut(
    eqns, targets, is_free, invar_cap, b_mand_set, cap_of=None,
    w_only=frozenset(),
):
    """Byte-minimal storable-var cut separating B-time values from W needs.

    Every var the W slice ultimately depends on must be either *free*
    (re-injectable params/side, plan consts), *stored* (part of the M_W
    context, costing its bytes), or *derivable* from stored/free vars by
    replaying cheap (``_REPLAYABLE``) equations.  Non-replayable equations
    that the base partition put in B (``b_mand_set``) produce storable
    origins; non-replayable equations left to W are not storable (they run
    only at W time) and propagate the need to their inputs.  ``w_only``
    vars (e.g. the W scan's own accumulator carries at body level) exist
    only at W time: they and anything computed from them can be consumed
    by W for free but never stored, so the cut lands on their B-side
    co-inputs instead.

    Returns the cut as a set of vars, or ``None`` when no finite cut exists
    / the cone is degenerate (caller falls back to the frontier cut).
    """
    producer: Dict[Any, int] = {}
    for i, e in enumerate(eqns):
        for ov in e.outvars:
            if isinstance(ov, _Var) and not isinstance(ov, _DropVar):
                producer[ov] = i

    cap_of = cap_of or _aval_bytes
    tgt = [v for v in targets if isinstance(v, _Var) and not is_free(v)]
    if not tgt:
        return set()

    nodes: List[Any] = []
    idx: Dict[Any, int] = {}
    caps: Dict[Any, int] = {}
    preds: Dict[Any, List[Any]] = {}
    origin: set = set()
    stack = list(dict.fromkeys(tgt))
    seen = set(stack)
    while stack:
        v = stack.pop()
        idx[v] = len(nodes)
        nodes.append(v)
        if v in w_only:
            caps[v] = _BIG  # exists only at W time; never storable
            continue
        i = producer.get(v)
        if i is None:
            c = invar_cap(v)
            if c is None:
                return None  # un-derivable, un-storable leaf
            caps[v] = c
            origin.add(v)
            continue
        e = eqns[i]
        replayable = (
            not isinstance(e, _SynthScanEqn)
            and e.primitive.name in _REPLAYABLE
        )
        if replayable:
            caps[v] = cap_of(v)
            ins = [
                u
                for u in e.invars
                if isinstance(u, _Var) and not is_free(u)
            ]
            preds[v] = ins
        elif i in b_mand_set:
            caps[v] = cap_of(v)  # materialized by B anyway: storable origin
            origin.add(v)
            continue
        else:
            caps[v] = _BIG  # runs only at W time: not storable
            ins = [
                u
                for u in e.invars
                if isinstance(u, _Var) and not is_free(u)
            ]
            preds[v] = ins
        for u in preds.get(v, ()):
            if u not in seen:
                seen.add(u)
                stack.append(u)

    if len(nodes) > 20000:
        return None  # pathological cone; keep the frontier cut

    # storable == B-computable: an origin, or a replayable chain over
    # B-computable inputs.  Anything downstream of a W-pinned equation only
    # exists at W time and must keep infinite capacity.
    order = sorted(
        nodes, key=lambda v: -1 if producer.get(v) is None else producer[v]
    )
    computable = set()
    for v in order:
        if v in origin:
            computable.add(v)
        elif v in preds and caps[v] < _BIG:
            # replayable: B-computable iff every stored/derived input is
            # (empty preds == derivable from free inputs alone)
            if all(u in computable for u in preds[v]):
                computable.add(v)
            else:
                caps[v] = _BIG

    # vertex-split flow network: v_in = 2k, v_out = 2k+1
    N = 2 * len(nodes) + 2
    S, T = N - 2, N - 1
    g = _Dinic(N)
    for v in nodes:
        k = idx[v]
        g.edge(2 * k, 2 * k + 1, caps[v])
    for v in nodes:
        for u in preds.get(v, ()):
            g.edge(2 * idx[u] + 1, 2 * idx[v], _BIG)
    for v in origin:
        g.edge(S, 2 * idx[v], _BIG)
    for v in dict.fromkeys(tgt):
        g.edge(2 * idx[v] + 1, T, _BIG)
    flow = g.max_flow(S, T)
    if flow >= _BIG:
        return None
    side = g.s_side(S)
    cut = set(
        v for v in nodes if side[2 * idx[v]] and not side[2 * idx[v] + 1]
    )
    return cut


# --------------------------------------------------------------------- #
# recursive scan split (the recurrent B/W split)
# --------------------------------------------------------------------- #
def _scan_arity(eqn):
    nc = eqn.params["num_consts"]
    nk = eqn.params["num_carry"]
    return nc, nk


def _split_one_scan(eqn, need_dx, need_dp):
    """Split a backward ``scan`` into a dx-only B scan + a dp replay W scan.

    Returns ``(b_eqn | None, w_eqn | None)`` or ``None`` when the equation
    should be left untouched.  The B scan keeps the recurrence (all carries
    the dx slice depends on) and, besides the dx-needed stacked outputs,
    emits the *per-step compact W context* as extra stacked outputs -- the
    byte-minimal body cut.  The W scan replays the dp slice of the body over
    that stacked context; dp-only accumulator carries (e.g. a dW summed
    across steps) move into it wholesale, so their GEMMs run at W time.
    """
    closed = eqn.params["jaxpr"]
    nc, nk = _scan_arity(eqn)
    length = eqn.params["length"]
    reverse = eqn.params["reverse"]
    body, body_consts = _inline_wrappers(closed.jaxpr, list(closed.consts))
    if any(isinstance(c, jax.core.Tracer) for c in body_consts):
        return None

    const_ivs = body.invars[:nc]
    carry_ivs = body.invars[nc : nc + nk]
    xs_ivs = body.invars[nc + nk :]
    carry_ovs = body.outvars[:nk]
    y_ovs = body.outvars[nk:]
    outer_consts = eqn.invars[:nc]
    outer_inits = eqn.invars[nc : nc + nk]
    outer_xs = eqn.invars[nc + nk :]
    outer_carry_outs = eqn.outvars[:nk]
    outer_ys = eqn.outvars[nk:]

    def _o_needed(ov, need):
        return isinstance(ov, _Var) and not isinstance(ov, _DropVar) and ov in need

    dx_ys = [j for j, ov in enumerate(outer_ys) if _o_needed(ov, need_dx)]
    dp_ys = [
        j
        for j, ov in enumerate(outer_ys)
        if _o_needed(ov, need_dp) and not _o_needed(ov, need_dx)
    ]
    eqn_dx = any(_o_needed(ov, need_dx) for ov in eqn.outvars)
    eqn_dp = any(_o_needed(ov, need_dp) for ov in eqn.outvars)

    # ---- case B: scan needed only for dp -> run whole in W, prune inputs #
    if not eqn_dx:
        if not eqn_dp:
            return None  # dead scan
        keep_c = set(
            i
            for i in range(nk)
            if _o_needed(outer_carry_outs[i], need_dp)
        )
        while True:
            targets = [carry_ovs[i] for i in keep_c] + [y_ovs[j] for j in dp_ys]
            wneed = _needed_vars(body.eqns, targets)
            grow = set(
                i
                for i in range(nk)
                if i not in keep_c
                and (carry_ivs[i] in wneed or carry_ovs[i] in wneed)
            )
            if not grow:
                break
            keep_c |= grow
        keep_c = sorted(keep_c)
        targets = [carry_ovs[i] for i in keep_c] + [y_ovs[j] for j in dp_ys]
        w_ids, wneed = _slice_eqns(body.eqns, targets, set())
        used_const = [k for k, v in enumerate(const_ivs) if v in wneed]
        used_xs = [k for k, v in enumerate(xs_ivs) if v in wneed]
        if (
            len(used_const) == nc
            and len(used_xs) == len(xs_ivs)
            and len(keep_c) == nk
            and len(dp_ys) == len(outer_ys)
        ):
            return None  # nothing prunable: keep the original equation
        w_eqn = _make_scan_half(
            f"{eqn.primitive.name}_w",
            body, body_consts, w_ids,
            const_pos=used_const, const_atoms=[outer_consts[k] for k in used_const],
            carry_pos=keep_c, carry_inits=[outer_inits[i] for i in keep_c],
            xs_pos=used_xs, xs_atoms=[outer_xs[k] for k in used_xs],
            ctx_vars=[], ctx_atoms=[],
            const_ivs=const_ivs, carry_ivs=carry_ivs, xs_ivs=xs_ivs,
            carry_ovs=carry_ovs, y_ovs=y_ovs,
            out_carries=keep_c,
            out_carry_atoms=[outer_carry_outs[i] for i in keep_c],
            out_ys=dp_ys, out_y_atoms=[outer_ys[j] for j in dp_ys],
            length=length, reverse=reverse,
        )
        return None, w_eqn

    # ---- case A: dual-use scan -> split the body ----------------------- #
    if not dp_ys and all(
        not _o_needed(outer_carry_outs[i], need_dp)
        or _o_needed(outer_carry_outs[i], need_dx)
        for i in range(nk)
    ):
        return None  # every dp-needed output is dx-needed anyway

    # carries whose final value is dp-only (or unused) may move to the W
    # scan -- unless the B slice of the body consumes their chain
    cand = set(
        i
        for i in range(nk)
        if not _o_needed(outer_carry_outs[i], need_dx)
    )
    while True:
        b_targets = [carry_ovs[i] for i in range(nk) if i not in cand] + [
            y_ovs[j] for j in dx_ys
        ]
        bneed = _needed_vars(body.eqns, b_targets)
        promote = set(
            i
            for i in cand
            if carry_ivs[i] in bneed or carry_ovs[i] in bneed
        )
        if not promote:
            break
        cand -= promote
    # W carries: candidates the dp side actually needs -- final value
    # dp-needed, or chain feeding the dp-only ys / other W-carry chains
    w_carries = set(
        i for i in cand if _o_needed(outer_carry_outs[i], need_dp)
    )
    while True:
        wneed0 = _needed_vars(
            body.eqns,
            [y_ovs[j] for j in dp_ys] + [carry_ovs[i] for i in w_carries],
        )
        grow = set(
            i
            for i in cand
            if i not in w_carries
            and (carry_ivs[i] in wneed0 or carry_ovs[i] in wneed0)
        )
        if not grow:
            break
        w_carries |= grow
    w_carries = sorted(w_carries)
    b_carries = [i for i in range(nk) if i not in cand]
    b_targets = [carry_ovs[i] for i in b_carries] + [y_ovs[j] for j in dx_ys]
    b_ids_base, _ = _slice_eqns(body.eqns, b_targets, set())
    b_mand_body = set(b_ids_base)

    w_targets = [carry_ovs[i] for i in w_carries] + [y_ovs[j] for j in dp_ys]
    if not w_targets:
        return None

    const_set = set(const_ivs)
    wcarry_in = set(carry_ivs[i] for i in w_carries)
    body_const_set = set(body.constvars)

    # note: const positions whose outer atom is a Literal are NOT free --
    # they join the cut like any const, so both half-scans receive them as
    # invars (the outer evaluator resolves Literal invars natively).
    # W-carry-ins are *not* free either: they exist only at W time, so the
    # cut must never select a value computed from one (the B half could
    # not materialize it) -- they ride ``w_only`` instead.
    def body_free(v):
        return v in body_const_set

    def w_avail(v):
        return v in body_const_set or v in wcarry_in

    def body_invar_cap(v):
        if v in const_set:
            return _aval_bytes(v)  # one copy, shared across steps
        if v in body_const_set:
            return None  # free; never reaches here
        # carry-in / xs: storing means a stacked per-step context
        return _aval_bytes(v) * int(length)

    cut = _byte_min_cut(
        body.eqns,
        w_targets,
        body_free,
        body_invar_cap,
        b_mand_body,
        cap_of=lambda v: _aval_bytes(v) * int(length),
        w_only=wcarry_in,
    )
    if cut is None:
        return None

    w_ids, wneed = _slice_eqns(body.eqns, w_targets, set(cut))
    # leaf validation: everything W consumes must be cut, free, or carried
    leaf_need = set()
    for i in w_ids:
        for v in body.eqns[i].invars:
            if isinstance(v, _Var) and v not in cut and not w_avail(v):
                leaf_need.add(v)
    prod_ok = set()
    for i in w_ids:
        for ov in body.eqns[i].outvars:
            prod_ok.add(ov)
    for v in w_targets:
        if isinstance(v, _Var) and v not in prod_ok and v not in cut and not w_avail(v):
            return None
    for v in leaf_need:
        if v not in prod_ok:
            return None

    const_cut = [k for k, v in enumerate(const_ivs) if v in cut]
    xs_cut = [k for k, v in enumerate(xs_ivs) if v in cut]
    ctx_vars = [
        v
        for v in sorted(
            (v for v in cut if v not in const_set and v not in set(xs_ivs)),
            key=lambda v: _body_order_key(body, v),
        )
    ]

    # B slice must additionally materialize the per-step context
    b_ids, bneed = _slice_eqns(
        body.eqns, b_targets + list(ctx_vars), set()
    )
    if bneed & wcarry_in:
        return None  # B half would need a W-only carry: cannot split
    b_const = [k for k, v in enumerate(const_ivs) if v in bneed]
    b_xs = [k for k, v in enumerate(xs_ivs) if v in bneed]

    ctx_atoms = [
        _fresh_var(
            jax.core.ShapedArray(
                (int(length),) + tuple(v.aval.shape), v.aval.dtype
            )
        )
        for v in ctx_vars
    ]
    b_eqn = _make_scan_half(
        f"{eqn.primitive.name}_b",
        body, body_consts, b_ids,
        const_pos=b_const, const_atoms=[outer_consts[k] for k in b_const],
        carry_pos=b_carries, carry_inits=[outer_inits[i] for i in b_carries],
        xs_pos=b_xs, xs_atoms=[outer_xs[k] for k in b_xs],
        ctx_vars=[], ctx_atoms=[],
        const_ivs=const_ivs, carry_ivs=carry_ivs, xs_ivs=xs_ivs,
        carry_ovs=carry_ovs, y_ovs=y_ovs,
        out_carries=b_carries,
        out_carry_atoms=[outer_carry_outs[i] for i in b_carries],
        out_ys=dx_ys, out_y_atoms=[outer_ys[j] for j in dx_ys],
        length=length, reverse=reverse,
        emit_ctx=ctx_vars, emit_ctx_atoms=ctx_atoms,
    )
    w_eqn = _make_scan_half(
        f"{eqn.primitive.name}_w",
        body, body_consts, w_ids,
        const_pos=const_cut, const_atoms=[outer_consts[k] for k in const_cut],
        carry_pos=list(w_carries), carry_inits=[outer_inits[i] for i in w_carries],
        xs_pos=xs_cut, xs_atoms=[outer_xs[k] for k in xs_cut],
        ctx_vars=ctx_vars, ctx_atoms=ctx_atoms,
        const_ivs=const_ivs, carry_ivs=carry_ivs, xs_ivs=xs_ivs,
        carry_ovs=carry_ovs, y_ovs=y_ovs,
        out_carries=list(w_carries),
        out_carry_atoms=[outer_carry_outs[i] for i in w_carries],
        out_ys=dp_ys, out_y_atoms=[outer_ys[j] for j in dp_ys],
        length=length, reverse=reverse,
    )
    return b_eqn, w_eqn


def _body_order_key(body, v):
    for i, e in enumerate(body.eqns):
        if v in e.outvars:
            return (1, i)
    try:
        return (0, body.invars.index(v))
    except ValueError:
        return (2, 0)


def _make_scan_half(
    name, body, body_consts, eqn_ids, *,
    const_pos, const_atoms, carry_pos, carry_inits, xs_pos, xs_atoms,
    ctx_vars, ctx_atoms, const_ivs, carry_ivs, xs_ivs, carry_ovs, y_ovs,
    out_carries, out_carry_atoms, out_ys, out_y_atoms, length, reverse,
    emit_ctx=(), emit_ctx_atoms=(),
):
    """Build one synthetic half-scan equation over a body slice.

    Inputs: selected outer consts, carry inits, stacked xs, and (for the W
    half) the stacked per-step context the B half emitted.  Outputs: the
    selected final carries and stacked ys, plus (for the B half) the stacked
    context.  Evaluation re-traces the body slice under ``jax.lax.scan``
    with the original ``reverse``/``length``, so per-index alignment and
    accumulation order match the unsplit scan exactly.
    """
    n_const = len(const_pos)
    n_carry = len(carry_pos)
    n_xs = len(xs_pos)
    const_vars = [const_ivs[k] for k in const_pos]
    carry_in_vars = [carry_ivs[i] for i in carry_pos]
    carry_out_vars = [carry_ovs[i] for i in carry_pos]
    xs_vars = [xs_ivs[k] for k in xs_pos]
    y_out_vars = [y_ovs[j] for j in out_ys]
    emit_ctx = list(emit_ctx)

    def run(invals):
        consts_v = invals[:n_const]
        inits = tuple(invals[n_const : n_const + n_carry])
        xs_v = tuple(invals[n_const + n_carry : n_const + n_carry + n_xs])
        ctx_v = tuple(invals[n_const + n_carry + n_xs :])

        def step(carry, sl):
            xsl = sl[:n_xs]
            ctxl = sl[n_xs:]
            env = dict(zip(body.constvars, body_consts))
            env.update(zip(const_vars, consts_v))
            env.update(zip(carry_in_vars, carry))
            env.update(zip(xs_vars, xsl))
            env.update(zip(ctx_vars, ctxl))
            _eval_eqns(body, eqn_ids, env)
            new_carry = tuple(_read(v, env) for v in carry_out_vars)
            ys = tuple(_read(v, env) for v in y_out_vars) + tuple(
                env[v] for v in emit_ctx
            )
            return new_carry, ys

        fin, ys = jax.lax.scan(
            step,
            inits,
            tuple(xs_v) + tuple(ctx_v),
            length=int(length),
            reverse=reverse,
        )
        return list(fin) + list(ys)

    invars = list(const_atoms) + list(carry_inits) + list(xs_atoms) + list(
        ctx_atoms
    )
    outvars = list(out_carry_atoms) + list(out_y_atoms) + list(emit_ctx_atoms)
    eqn = _SynthScanEqn(name, invars, outvars, run)
    # introspection (tests assert e.g. that the per-step wgrad GEMMs moved
    # into the W half): the body slice this half evaluates per step
    eqn.body = body
    eqn.body_eqn_ids = list(eqn_ids)
    eqn.n_ctx = len(ctx_atoms) + len(emit_ctx_atoms)
    return eqn


def _split_scans(ir: _FlatIR, need_dx, need_dp) -> bool:
    """Rewrite splittable ``scan`` equations in place; True when changed."""
    changed = False
    new_eqns: List[Any] = []
    for eqn in ir.eqns:
        if (
            isinstance(eqn, _SynthScanEqn)
            or getattr(eqn.primitive, "name", "") != "scan"
        ):
            new_eqns.append(eqn)
            continue
        try:
            halves = _split_one_scan(eqn, need_dx, need_dp)
        except (KeyError, ValueError, TypeError):
            halves = None
        if halves is None:
            new_eqns.append(eqn)
            continue
        b_eqn, w_eqn = halves
        if b_eqn is not None:
            new_eqns.append(b_eqn)
        if w_eqn is not None:
            new_eqns.append(w_eqn)
        changed = True
    if changed:
        ir.eqns = new_eqns
    return changed


# --------------------------------------------------------------------- #
# the compact partition: scan split + byte-minimal context
# --------------------------------------------------------------------- #
def _compact_partition(ir: _FlatIR, n_p: int, n_s: int, dp_vars, dx_vars):
    """(b_eqns, w_eqns, cut_vars, reinject) or None -> frontier fallback."""
    need_dx = _needed_vars(ir.eqns, dx_vars)
    need_dp = _needed_vars(ir.eqns, dp_vars)
    if _split_scans(ir, need_dx, need_dp):
        need_dx = _needed_vars(ir.eqns, dx_vars)

    invar_idx = {v: i for i, v in enumerate(ir.invars)}
    constset = set(ir.constvars)

    def is_free(v):
        if v in constset:
            return True
        i = invar_idx.get(v)
        return i is not None and i < n_p + n_s

    def invar_cap(v):
        if invar_idx.get(v) is None:
            return None
        return _aval_bytes(v)

    b_mand = set(
        i
        for i, e in enumerate(ir.eqns)
        if any(
            isinstance(ov, _Var)
            and not isinstance(ov, _DropVar)
            and ov in need_dx
            for ov in e.outvars
        )
    )
    cut = _byte_min_cut(ir.eqns, dp_vars, is_free, invar_cap, b_mand)
    if cut is None:
        return None

    producer = {}
    for i, e in enumerate(ir.eqns):
        for ov in e.outvars:
            if isinstance(ov, _Var) and not isinstance(ov, _DropVar):
                producer[ov] = i

    def order_key(v):
        i = producer.get(v)
        if i is None:
            return (0, invar_idx.get(v, 0))
        return (1, i)

    cut_vars = sorted(cut, key=order_key)

    w_eqns, w_need = _slice_eqns(ir.eqns, list(dp_vars), cut)
    # consistency: W may evaluate replayable equations, its own pinned
    # equations, and synthetic W scans -- never a non-replayable equation
    # the B slice owns
    for i in w_eqns:
        e = ir.eqns[i]
        replayable = (
            not isinstance(e, _SynthScanEqn)
            and e.primitive.name in _REPLAYABLE
        )
        if not replayable and i in b_mand:
            return None
    for v in w_need:
        if producer.get(v) is None and not is_free(v) and v not in cut:
            return None

    b_eqns, _ = _slice_eqns(ir.eqns, list(dx_vars) + cut_vars, set())

    reinject: Dict[Any, int] = {}
    for i in w_eqns:
        for v in ir.eqns[i].invars:
            if isinstance(v, _Var):
                j = invar_idx.get(v)
                if j is not None and j < n_p + n_s:
                    reinject[v] = j
    for v in dp_vars:
        if isinstance(v, _Var):
            j = invar_idx.get(v)
            if j is not None and j < n_p + n_s:
                reinject[v] = j
    return b_eqns, w_eqns, cut_vars, reinject


class _AutoFBW(FBWModule):
    def __init__(
        self,
        f: Callable[[PyTree, PyTree, PyTree], PyTree],
        init_fn: Optional[Callable[[jax.Array], PyTree]] = None,
        name: str = "auto",
        compact: Optional[bool] = None,
    ):
        self.f = f
        self._init_fn = init_fn
        self.name = name
        self.compact = _COMPACT_DEFAULT if compact is None else bool(compact)
        self._treedef = None
        self._spec: Optional[List[Tuple[int, int]]] = None
        self._split: Optional[_SplitPlan] = None

    def init(self, key):
        if self._init_fn is None:
            raise NotImplementedError(f"{self.name}: no init_fn provided")
        return self._init_fn(key)

    # -- forward ---------------------------------------------------------- #
    def fwd(self, params, x, side):
        y, pullback = jax.vjp(lambda p, xx: self.f(p, xx, side), params, x)
        leaves, treedef = jax.tree_util.tree_flatten(pullback)
        self._treedef = treedef
        by_id = {}
        for i, leaf in enumerate(jax.tree_util.tree_leaves(params)):
            by_id.setdefault(id(leaf), (_PARAM, i))
        for i, leaf in enumerate(jax.tree_util.tree_leaves(side)):
            by_id.setdefault(id(leaf), (_SIDE, i))
        spec: List[Tuple[int, int]] = []
        stored = []
        for leaf in leaves:
            hit = by_id.get(id(leaf))
            if hit is not None:
                spec.append(hit)
            else:
                spec.append((_STORE, len(stored)))
                stored.append(leaf)
        self._spec = spec
        return y, tuple(stored)

    def _rebuild(self, params, stored, side):
        if self._treedef is None or self._spec is None:
            raise RuntimeError(
                f"{self.name}: fwd must be traced before bwd (call "
                "ensure_traced or run fwd under jax.eval_shape first)"
            )
        p_leaves = jax.tree_util.tree_leaves(params)
        s_leaves = jax.tree_util.tree_leaves(side)
        leaves = []
        for kind, i in self._spec:
            if kind == _STORE:
                leaves.append(stored[i])
            elif kind == _PARAM:
                leaves.append(p_leaves[i])
            else:
                leaves.append(s_leaves[i])
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    # -- backward-jaxpr partition ------------------------------------------ #
    def _ensure_split(self, params, res, dy, side) -> _SplitPlan:
        key = _avals_key(params, res, dy, side)
        if self._split is not None and self._split.key == key:
            return self._split

        p_leaves = jax.tree_util.tree_leaves(params)
        s_leaves = jax.tree_util.tree_leaves(side)
        dy_leaves, dy_tree = jax.tree_util.tree_flatten(dy)
        n_p, n_s = len(p_leaves), len(s_leaves)

        def joint(pl, sl, st, dyl):
            p2 = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(params), pl
            )
            s2 = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(side), sl
            )
            pb = self._rebuild(p2, st, s2)
            dp, dx = pb(jax.tree_util.tree_unflatten(dy_tree, dyl))
            return dp, dx

        closed, out_shape = jax.make_jaxpr(joint, return_shape=True)(
            p_leaves, s_leaves, list(res), dy_leaves
        )
        if any(isinstance(c, jax.core.Tracer) for c in closed.consts):
            raise RuntimeError(
                f"{self.name}: backward jaxpr captured tracer constants; "
                "route all data through params/x/side"
            )
        jaxpr = closed.jaxpr
        consts = list(closed.consts)
        dp_shape, dx_shape = out_shape
        dp_tree = jax.tree_util.tree_structure(dp_shape)
        dx_tree = jax.tree_util.tree_structure(dx_shape)
        n_dp = dp_tree.num_leaves

        part = None
        if self.compact:
            ir, consts_i = _inline_wrappers(jaxpr, consts)
            if not any(isinstance(c, jax.core.Tracer) for c in consts_i):
                dp_vars = list(ir.outvars[:n_dp])
                dx_vars = list(ir.outvars[n_dp:])
                part = _compact_partition(ir, n_p, n_s, dp_vars, dx_vars)
                if part is not None:
                    b_eqns, w_eqns, cut_vars, reinject = part
                    jaxpr, consts = ir, consts_i

        if part is None:
            # frontier cut (the legacy partition; also the compat baseline)
            dp_vars = list(jaxpr.outvars[:n_dp])
            dx_vars = list(jaxpr.outvars[n_dp:])
            need_dx = _needed_vars(jaxpr.eqns, dx_vars)
            need_dp = _needed_vars(jaxpr.eqns, dp_vars)
            b_eqns = [
                i
                for i, e in enumerate(jaxpr.eqns)
                if any(ov in need_dx for ov in e.outvars)
            ]
            b_set = set(b_eqns)
            w_eqns = [
                i
                for i, e in enumerate(jaxpr.eqns)
                if i not in b_set and any(ov in need_dp for ov in e.outvars)
            ]
            w_prod = set(ov for i in w_eqns for ov in jaxpr.eqns[i].outvars)
            invar_idx = {v: i for i, v in enumerate(jaxpr.invars)}
            constvars = set(jaxpr.constvars)

            seen = set()
            cut_vars = []
            reinject = {}

            def classify(v):
                if not isinstance(v, _Var) or v in seen:
                    return
                seen.add(v)
                if v in w_prod or v in constvars:
                    return
                i = invar_idx.get(v)
                if i is not None and i < n_p + n_s:
                    reinject[v] = i  # param / side leaf: re-injected
                    return
                cut_vars.append(v)  # B-produced value or stored/dy leaf: M_W

            for i in w_eqns:
                for v in jaxpr.eqns[i].invars:
                    classify(v)
            for v in dp_vars:
                classify(v)

        self._split = _SplitPlan(
            jaxpr=jaxpr,
            consts=consts,
            b_eqns=b_eqns,
            w_eqns=w_eqns,
            cut_vars=cut_vars,
            reinject=reinject,
            dp_vars=dp_vars,
            dx_vars=dx_vars,
            dp_tree=dp_tree,
            dx_tree=dx_tree,
            n_p=n_p,
            n_s=n_s,
            wgrad_routes=_find_wgrad_routes(jaxpr, w_eqns, dp_vars),
            key=key,
        )
        return self._split

    # -- B: input gradient; emits the compact M_W context ------------------ #
    def bwd_x(self, params, res, dy, side):
        plan = self._ensure_split(params, res, dy, side)
        env = dict(zip(plan.jaxpr.constvars, plan.consts))
        flat = (
            jax.tree_util.tree_leaves(params)
            + jax.tree_util.tree_leaves(side)
            + list(res)
            + jax.tree_util.tree_leaves(dy)
        )
        env.update(zip(plan.jaxpr.invars, flat))
        _eval_eqns(plan.jaxpr, plan.b_eqns, env)
        dx = jax.tree_util.tree_unflatten(
            plan.dx_tree, [_read(v, env) for v in plan.dx_vars]
        )
        wctx = tuple(env[v] for v in plan.cut_vars)
        return dx, wctx

    # -- W: parameter gradient from the M_W context alone ------------------- #
    def bwd_w(self, params, wctx, side, acc=None):
        plan = self._split
        if plan is None:
            raise RuntimeError(
                f"{self.name}: bwd_x must be traced before bwd_w"
            )
        got = tuple(
            (tuple(w.shape), jnp.result_type(w).name) for w in wctx
        )
        want = tuple(
            (tuple(v.aval.shape), jnp.result_type(v.aval.dtype).name)
            for v in plan.cut_vars
        )
        if got != want:
            raise RuntimeError(
                f"{self.name}: wctx does not match the cached split (module "
                f"re-traced at different shapes between bwd_x and bwd_w?): "
                f"got {got[:4]}..., want {want[:4]}..."
            )
        env = dict(zip(plan.jaxpr.constvars, plan.consts))
        flat_ps = jax.tree_util.tree_leaves(params) + jax.tree_util.tree_leaves(
            side
        )
        for v, i in plan.reinject.items():
            env[v] = flat_ps[i]
        env.update(zip(plan.cut_vars, wctx))

        fused: Dict[int, Any] = {}
        skip = set()
        if acc is not None:
            acc_leaves = jax.tree_util.tree_leaves(acc)
            for k, route in enumerate(plan.wgrad_routes):
                if route is None:
                    continue
                a_leaf = acc_leaves[k]
                if jnp.result_type(a_leaf) != jnp.float32:
                    continue  # the fused kernel accumulates in fp32 only
                fused[k] = route
                skip |= set(route[3])
        _eval_eqns(plan.jaxpr, plan.w_eqns, env, skip=skip)

        if acc is None:
            grads = [_read(v, env) for v in plan.dp_vars]
            return jax.tree_util.tree_unflatten(plan.dp_tree, grads)

        from ..kernels.ops import wgrad_accum

        out = []
        for k, (v, a_leaf) in enumerate(zip(plan.dp_vars, acc_leaves)):
            route = fused.get(k)
            if route is not None:
                _, a_var, g_var, _ = route
                a = env[a_var]
                g = env[g_var]
                out.append(
                    wgrad_accum(
                        a.reshape(-1, a.shape[-1]),
                        g.reshape(-1, g.shape[-1]),
                        a_leaf,
                    )
                )
            else:
                g = _read(v, env)
                out.append(a_leaf + g.astype(a_leaf.dtype))
        return jax.tree_util.tree_unflatten(plan.dp_tree, out)

    def ensure_traced(self, params, x, side) -> None:
        """Populate the static residual spec without running any compute."""
        jax.eval_shape(lambda p, xx, sd: self.fwd(p, xx, sd), params, x, side)


def auto_fbw(
    f: Callable[[PyTree, PyTree, PyTree], PyTree],
    init_fn: Optional[Callable[[jax.Array], PyTree]] = None,
    name: str = "auto",
    compact: Optional[bool] = None,
) -> _AutoFBW:
    """Split any ``f(params, x, side) -> y`` into true F/B/W passes.

    ``compact`` (default: on, unless ``REPRO_SPLIT_COMPAT=1``) selects the
    byte-minimal W-context: wrapper inlining, the recursive scan split, and
    the min-cut with cheap W-side replay.  ``compact=False`` keeps the
    legacy frontier cut -- the pre-split baseline the measured-memory tests
    compare against.
    """
    return _AutoFBW(f, init_fn, name, compact=compact)


# --------------------------------------------------------------------- #
# sequential composition (a pipeline chunk = this stage's layer group)
# --------------------------------------------------------------------- #
class SequentialFBW(FBWModule):
    """Compose FBW modules; F runs left-to-right, B right-to-left.

    During B, each sub-module emits its own compact M_W context; the tuple
    of these per-block contexts is exactly the paper's "extra gradients
    (nabla_z L) kept for W" (Table 1) plus the wgrad matmul inputs.
    """

    def __init__(self, modules: Sequence[FBWModule], name: str = "seq"):
        self.modules = list(modules)
        self.name = name

    def init(self, key):
        keys = jax.random.split(key, len(self.modules))
        return tuple(mod.init(k) for mod, k in zip(self.modules, keys))

    def fwd(self, params, x, side):
        res_all = []
        for mod, p in zip(self.modules, params):
            x, res = mod.fwd(p, x, side)
            res_all.append(res)
        return x, tuple(res_all)

    def bwd_x(self, params, res, dy, side):
        wctx_all: List[PyTree] = [None] * len(self.modules)
        for i in reversed(range(len(self.modules))):
            dy, wctx = self.modules[i].bwd_x(params[i], res[i], dy, side)
            wctx_all[i] = wctx
        return dy, tuple(wctx_all)

    def bwd_w(self, params, wctx, side, acc=None):
        if acc is None:
            return tuple(
                mod.bwd_w(p, w, side)
                for mod, p, w in zip(self.modules, params, wctx)
            )
        return tuple(
            mod.bwd_w(p, w, side, acc=a)
            for mod, p, w, a in zip(self.modules, params, wctx, acc)
        )

    def ensure_traced(self, params, x, side) -> None:
        jax.eval_shape(lambda p, xx, sd: self.fwd(p, xx, sd), params, x, side)


def loss_seed(loss: jax.Array) -> jax.Array:
    """Cotangent that seeds B at the loss position."""
    return jnp.ones_like(loss)
