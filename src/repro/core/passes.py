"""F/B/W split machinery -- the paper's enabling primitive (Sec. 1, Fig. 1).

Every pipeline-stage computation is an :class:`FBWModule` with three passes:

  * ``fwd(params, x, side)   -> (y, res)``        -- forward, saving residuals
  * ``bwd_x(params, res, dy, side) -> (dx, wctx)`` -- input gradient (B)
  * ``bwd_w(params, wctx, side)    -> grads``      -- parameter gradient (W)

``B`` carries the inter-stage dependency chain; ``W`` is free to be scheduled
any time after its ``B`` on the same stage -- exactly the degree of freedom
the zero-bubble schedules exploit.

:func:`auto_fbw` derives a *true* split for any JAX function by partitioning
the backward jaxpr (no rematerialization, no pullback rebuild at W):

  1. ``fwd`` runs ``jax.vjp`` once; the pullback closure is a pytree
     (``jax.tree_util.Partial``), so its residuals are extracted by
     ``tree_flatten`` and stored in pipeline buffers.  Leaves that are merely
     forwarded parameter / side-input tracers are detected by object identity
     and *not* stored -- they are re-injected from the stage's own
     params/side at B/W time.
  2. On the first backward trace, the full pullback application
     ``(params, side, res, dy) -> (dparams, dx)`` is staged to a jaxpr and
     partitioned: an equation belongs to the **B slice** iff its outputs are
     (transitively) needed for ``dx``; the remaining equations needed for
     ``dparams`` form the **W slice**.  The values crossing the cut -- the
     wgrad closure inputs: per-matmul input activations plus the upstream
     cotangents materialized by B -- are the paper's ``M_W`` context.
  3. ``bwd_x`` evaluates only the B slice and returns ``(dx, wctx)`` where
     ``wctx`` is the tuple of cut values.  The F->B residuals are dead after
     this point: the executor frees their slot at B.
  4. ``bwd_w`` evaluates only the W slice from ``wctx`` plus re-injected
     params/side.  Nothing is recomputed; the residuals are gone.

FLOPs therefore match the paper's Table 1 split (B and W each carry one of
the two backward matmuls per forward matmul), and the *memory* now matches
the paper's accounting too: only ``M_W`` survives past B.  ``bwd_w``
optionally takes a gradient accumulator; terminal ``dW = a^T @ g`` outer
products are then routed through the fused accumulation kernel
(:func:`repro.kernels.ops.wgrad_accum`, paper App. A) when dtypes allow.
"""

from __future__ import annotations

import dataclasses
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

try:  # jax >= 0.4.36 re-exports the core IR types here
    from jax.extend import core as _jcore
except ImportError:  # pragma: no cover
    import jax.core as _jcore

_Var = _jcore.Var
_Literal = _jcore.Literal
_DropVar = getattr(_jcore, "DropVar", None) or jax.core.DropVar

__all__ = ["FBWModule", "auto_fbw", "SequentialFBW", "loss_seed"]

PyTree = Any


class FBWModule:
    """Protocol + base class for split-backward modules."""

    #: set by subclasses / factories
    name: str = "fbw"

    def init(self, key: jax.Array) -> PyTree:
        raise NotImplementedError

    def fwd(self, params: PyTree, x: PyTree, side: PyTree) -> Tuple[PyTree, PyTree]:
        raise NotImplementedError

    def bwd_x(
        self, params: PyTree, res: PyTree, dy: PyTree, side: PyTree
    ) -> Tuple[PyTree, PyTree]:
        raise NotImplementedError

    def bwd_w(
        self, params: PyTree, wctx: PyTree, side: PyTree, acc: Optional[PyTree] = None
    ) -> PyTree:
        """Parameter gradients from the B pass's wctx alone (the paper's
        M_W context).  The F->B residuals are *not* available: they are
        freed when B completes.  When ``acc`` (a pytree matching params) is
        given, returns ``acc + grads`` with terminal outer products fused
        through the wgrad-accumulation kernel where dtypes allow."""
        raise NotImplementedError

    # convenience: fused backward for parity testing against jax.grad
    def bwd_full(self, params, res, dy, side):
        dx, wctx = self.bwd_x(params, res, dy, side)
        return dx, self.bwd_w(params, wctx, side)


# --------------------------------------------------------------------- #
# automatic split
# --------------------------------------------------------------------- #
_STORE, _PARAM, _SIDE = 0, 1, 2


@dataclasses.dataclass
class _SplitPlan:
    """Static partition of one backward jaxpr into B / W slices."""

    jaxpr: Any  # jax core Jaxpr
    consts: List[Any]
    b_eqns: List[int]
    w_eqns: List[int]
    cut_vars: List[Any]  # values riding the M_W context, in capture order
    reinject: Dict[Any, int]  # var -> flat (params+side) leaf index
    dp_vars: List[Any]
    dx_vars: List[Any]
    dp_tree: Any
    dx_tree: Any
    n_p: int
    n_s: int
    # dp leaf -> ("fuse", a_var, g_var, {eqn ids to skip}) | None
    wgrad_routes: List[Optional[Tuple]]
    key: Tuple


def _avals_key(*trees):
    return tuple(
        (tuple(l.shape), jnp.result_type(l).name)
        for l in jax.tree_util.tree_leaves(trees)
    )


def _eval_eqns(jaxpr, eqn_ids, env, skip=()):
    for i in eqn_ids:
        if i in skip:
            continue
        eqn = jaxpr.eqns[i]
        invals = [
            v.val if isinstance(v, _Literal) else env[v] for v in eqn.invars
        ]
        ans = eqn.primitive.bind(*invals, **eqn.params)
        outs = ans if eqn.primitive.multiple_results else [ans]
        for var, val in zip(eqn.outvars, outs):
            if not isinstance(var, _DropVar):
                env[var] = val


def _read(v, env):
    return jnp.asarray(v.val) if isinstance(v, _Literal) else env[v]


def _find_wgrad_routes(jaxpr, w_eqns, dp_vars):
    """Terminal ``dW = a^T @ g`` patterns eligible for fused accumulation.

    Matches a dp output produced (within the W slice) by either
    ``dot_general(u, v)`` contracting dim 0 with dim 0 (dW = u^T v), or the
    same followed by a rank-2 ``transpose`` (dW = v^T u).  The matched
    equations can then be *replaced* by one `wgrad_accum` call.
    """
    producer = {}
    use_count: Dict[Any, int] = {}
    w_set = set(w_eqns)
    for i, eqn in enumerate(jaxpr.eqns):
        for ov in eqn.outvars:
            if not isinstance(ov, _DropVar):
                producer[ov] = i
        for v in eqn.invars:
            if isinstance(v, _Var):
                use_count[v] = use_count.get(v, 0) + 1
    for v in jaxpr.outvars:
        if isinstance(v, _Var):
            use_count[v] = use_count.get(v, 0) + 1

    def _is_wgrad_dot(eqn):
        # dW = a^T @ g with the token dims flattened: contract every leading
        # dim of both rank-k operands (k >= 2), no batch dims.
        if eqn.primitive.name != "dot_general":
            return False
        (lc, rc), (lb, rb) = eqn.params["dimension_numbers"]
        if lb or rb:
            return False
        if not all(
            isinstance(v, _Var) and len(v.aval.shape) >= 2 for v in eqn.invars
        ):
            return False
        k = len(eqn.invars[0].aval.shape)
        lead = tuple(range(k - 1))
        return (
            len(eqn.invars[1].aval.shape) == k
            and tuple(lc) == lead
            and tuple(rc) == lead
        )

    routes = []
    for dp in dp_vars:
        route = None
        i = producer.get(dp)
        if i is not None and i in w_set and use_count.get(dp, 0) == 1:
            eqn = jaxpr.eqns[i]
            if _is_wgrad_dot(eqn):
                u, v = eqn.invars
                route = ("fuse", u, v, frozenset([i]))
            elif (
                eqn.primitive.name == "transpose"
                and tuple(eqn.params["permutation"]) == (1, 0)
                and isinstance(eqn.invars[0], _Var)
                and use_count.get(eqn.invars[0], 0) == 1
            ):
                j = producer.get(eqn.invars[0])
                if j is not None and j in w_set and _is_wgrad_dot(jaxpr.eqns[j]):
                    u, v = jaxpr.eqns[j].invars
                    route = ("fuse", v, u, frozenset([i, j]))
        routes.append(route)
    return routes


class _AutoFBW(FBWModule):
    def __init__(
        self,
        f: Callable[[PyTree, PyTree, PyTree], PyTree],
        init_fn: Optional[Callable[[jax.Array], PyTree]] = None,
        name: str = "auto",
    ):
        self.f = f
        self._init_fn = init_fn
        self.name = name
        self._treedef = None
        self._spec: Optional[List[Tuple[int, int]]] = None
        self._split: Optional[_SplitPlan] = None

    def init(self, key):
        if self._init_fn is None:
            raise NotImplementedError(f"{self.name}: no init_fn provided")
        return self._init_fn(key)

    # -- forward ---------------------------------------------------------- #
    def fwd(self, params, x, side):
        y, pullback = jax.vjp(lambda p, xx: self.f(p, xx, side), params, x)
        leaves, treedef = jax.tree_util.tree_flatten(pullback)
        self._treedef = treedef
        by_id = {}
        for i, leaf in enumerate(jax.tree_util.tree_leaves(params)):
            by_id.setdefault(id(leaf), (_PARAM, i))
        for i, leaf in enumerate(jax.tree_util.tree_leaves(side)):
            by_id.setdefault(id(leaf), (_SIDE, i))
        spec: List[Tuple[int, int]] = []
        stored = []
        for leaf in leaves:
            hit = by_id.get(id(leaf))
            if hit is not None:
                spec.append(hit)
            else:
                spec.append((_STORE, len(stored)))
                stored.append(leaf)
        self._spec = spec
        return y, tuple(stored)

    def _rebuild(self, params, stored, side):
        if self._treedef is None or self._spec is None:
            raise RuntimeError(
                f"{self.name}: fwd must be traced before bwd (call "
                "ensure_traced or run fwd under jax.eval_shape first)"
            )
        p_leaves = jax.tree_util.tree_leaves(params)
        s_leaves = jax.tree_util.tree_leaves(side)
        leaves = []
        for kind, i in self._spec:
            if kind == _STORE:
                leaves.append(stored[i])
            elif kind == _PARAM:
                leaves.append(p_leaves[i])
            else:
                leaves.append(s_leaves[i])
        return jax.tree_util.tree_unflatten(self._treedef, leaves)

    # -- backward-jaxpr partition ------------------------------------------ #
    def _ensure_split(self, params, res, dy, side) -> _SplitPlan:
        key = _avals_key(params, res, dy, side)
        if self._split is not None and self._split.key == key:
            return self._split

        p_leaves = jax.tree_util.tree_leaves(params)
        s_leaves = jax.tree_util.tree_leaves(side)
        dy_leaves, dy_tree = jax.tree_util.tree_flatten(dy)
        n_p, n_s = len(p_leaves), len(s_leaves)

        def joint(pl, sl, st, dyl):
            p2 = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(params), pl
            )
            s2 = jax.tree_util.tree_unflatten(
                jax.tree_util.tree_structure(side), sl
            )
            pb = self._rebuild(p2, st, s2)
            dp, dx = pb(jax.tree_util.tree_unflatten(dy_tree, dyl))
            return dp, dx

        closed, out_shape = jax.make_jaxpr(joint, return_shape=True)(
            p_leaves, s_leaves, list(res), dy_leaves
        )
        if any(isinstance(c, jax.core.Tracer) for c in closed.consts):
            raise RuntimeError(
                f"{self.name}: backward jaxpr captured tracer constants; "
                "route all data through params/x/side"
            )
        jaxpr = closed.jaxpr
        dp_shape, dx_shape = out_shape
        dp_tree = jax.tree_util.tree_structure(dp_shape)
        dx_tree = jax.tree_util.tree_structure(dx_shape)
        n_dp = dp_tree.num_leaves
        dp_vars = list(jaxpr.outvars[:n_dp])
        dx_vars = list(jaxpr.outvars[n_dp:])

        def needed(targets):
            need = set(v for v in targets if isinstance(v, _Var))
            for eqn in reversed(jaxpr.eqns):
                if any(ov in need for ov in eqn.outvars):
                    need.update(v for v in eqn.invars if isinstance(v, _Var))
            return need

        need_dx = needed(dx_vars)
        need_dp = needed(dp_vars)
        b_eqns = [
            i
            for i, e in enumerate(jaxpr.eqns)
            if any(ov in need_dx for ov in e.outvars)
        ]
        b_set = set(b_eqns)
        w_eqns = [
            i
            for i, e in enumerate(jaxpr.eqns)
            if i not in b_set and any(ov in need_dp for ov in e.outvars)
        ]
        w_prod = set(ov for i in w_eqns for ov in jaxpr.eqns[i].outvars)
        invar_idx = {v: i for i, v in enumerate(jaxpr.invars)}
        constvars = set(jaxpr.constvars)

        seen = set()
        cut_vars: List[Any] = []
        reinject: Dict[Any, int] = {}

        def classify(v):
            if not isinstance(v, _Var) or v in seen:
                return
            seen.add(v)
            if v in w_prod or v in constvars:
                return
            i = invar_idx.get(v)
            if i is not None and i < n_p + n_s:
                reinject[v] = i  # param / side leaf: re-injected, not stored
                return
            cut_vars.append(v)  # B-produced value or stored/dy leaf: M_W

        for i in w_eqns:
            for v in jaxpr.eqns[i].invars:
                classify(v)
        for v in dp_vars:
            classify(v)

        self._split = _SplitPlan(
            jaxpr=jaxpr,
            consts=list(closed.consts),
            b_eqns=b_eqns,
            w_eqns=w_eqns,
            cut_vars=cut_vars,
            reinject=reinject,
            dp_vars=dp_vars,
            dx_vars=dx_vars,
            dp_tree=dp_tree,
            dx_tree=dx_tree,
            n_p=n_p,
            n_s=n_s,
            wgrad_routes=_find_wgrad_routes(jaxpr, w_eqns, dp_vars),
            key=key,
        )
        return self._split

    # -- B: input gradient; emits the compact M_W context ------------------ #
    def bwd_x(self, params, res, dy, side):
        plan = self._ensure_split(params, res, dy, side)
        env = dict(zip(plan.jaxpr.constvars, plan.consts))
        flat = (
            jax.tree_util.tree_leaves(params)
            + jax.tree_util.tree_leaves(side)
            + list(res)
            + jax.tree_util.tree_leaves(dy)
        )
        env.update(zip(plan.jaxpr.invars, flat))
        _eval_eqns(plan.jaxpr, plan.b_eqns, env)
        dx = jax.tree_util.tree_unflatten(
            plan.dx_tree, [_read(v, env) for v in plan.dx_vars]
        )
        wctx = tuple(env[v] for v in plan.cut_vars)
        return dx, wctx

    # -- W: parameter gradient from the M_W context alone ------------------- #
    def bwd_w(self, params, wctx, side, acc=None):
        plan = self._split
        if plan is None:
            raise RuntimeError(
                f"{self.name}: bwd_x must be traced before bwd_w"
            )
        got = tuple(
            (tuple(w.shape), jnp.result_type(w).name) for w in wctx
        )
        want = tuple(
            (tuple(v.aval.shape), jnp.result_type(v.aval.dtype).name)
            for v in plan.cut_vars
        )
        if got != want:
            raise RuntimeError(
                f"{self.name}: wctx does not match the cached split (module "
                f"re-traced at different shapes between bwd_x and bwd_w?): "
                f"got {got[:4]}..., want {want[:4]}..."
            )
        env = dict(zip(plan.jaxpr.constvars, plan.consts))
        flat_ps = jax.tree_util.tree_leaves(params) + jax.tree_util.tree_leaves(
            side
        )
        for v, i in plan.reinject.items():
            env[v] = flat_ps[i]
        env.update(zip(plan.cut_vars, wctx))

        fused: Dict[int, Any] = {}
        skip = set()
        if acc is not None:
            acc_leaves = jax.tree_util.tree_leaves(acc)
            for k, route in enumerate(plan.wgrad_routes):
                if route is None:
                    continue
                a_leaf = acc_leaves[k]
                if jnp.result_type(a_leaf) != jnp.float32:
                    continue  # the fused kernel accumulates in fp32 only
                fused[k] = route
                skip |= set(route[3])
        _eval_eqns(plan.jaxpr, plan.w_eqns, env, skip=skip)

        if acc is None:
            grads = [_read(v, env) for v in plan.dp_vars]
            return jax.tree_util.tree_unflatten(plan.dp_tree, grads)

        from ..kernels.ops import wgrad_accum

        out = []
        for k, (v, a_leaf) in enumerate(zip(plan.dp_vars, acc_leaves)):
            route = fused.get(k)
            if route is not None:
                _, a_var, g_var, _ = route
                a = env[a_var]
                g = env[g_var]
                out.append(
                    wgrad_accum(
                        a.reshape(-1, a.shape[-1]),
                        g.reshape(-1, g.shape[-1]),
                        a_leaf,
                    )
                )
            else:
                g = _read(v, env)
                out.append(a_leaf + g.astype(a_leaf.dtype))
        return jax.tree_util.tree_unflatten(plan.dp_tree, out)

    def ensure_traced(self, params, x, side) -> None:
        """Populate the static residual spec without running any compute."""
        jax.eval_shape(lambda p, xx, sd: self.fwd(p, xx, sd), params, x, side)


def auto_fbw(
    f: Callable[[PyTree, PyTree, PyTree], PyTree],
    init_fn: Optional[Callable[[jax.Array], PyTree]] = None,
    name: str = "auto",
) -> _AutoFBW:
    """Split any ``f(params, x, side) -> y`` into true F/B/W passes."""
    return _AutoFBW(f, init_fn, name)


# --------------------------------------------------------------------- #
# sequential composition (a pipeline chunk = this stage's layer group)
# --------------------------------------------------------------------- #
class SequentialFBW(FBWModule):
    """Compose FBW modules; F runs left-to-right, B right-to-left.

    During B, each sub-module emits its own compact M_W context; the tuple
    of these per-block contexts is exactly the paper's "extra gradients
    (nabla_z L) kept for W" (Table 1) plus the wgrad matmul inputs.
    """

    def __init__(self, modules: Sequence[FBWModule], name: str = "seq"):
        self.modules = list(modules)
        self.name = name

    def init(self, key):
        keys = jax.random.split(key, len(self.modules))
        return tuple(mod.init(k) for mod, k in zip(self.modules, keys))

    def fwd(self, params, x, side):
        res_all = []
        for mod, p in zip(self.modules, params):
            x, res = mod.fwd(p, x, side)
            res_all.append(res)
        return x, tuple(res_all)

    def bwd_x(self, params, res, dy, side):
        wctx_all: List[PyTree] = [None] * len(self.modules)
        for i in reversed(range(len(self.modules))):
            dy, wctx = self.modules[i].bwd_x(params[i], res[i], dy, side)
            wctx_all[i] = wctx
        return dy, tuple(wctx_all)

    def bwd_w(self, params, wctx, side, acc=None):
        if acc is None:
            return tuple(
                mod.bwd_w(p, w, side)
                for mod, p, w in zip(self.modules, params, wctx)
            )
        return tuple(
            mod.bwd_w(p, w, side, acc=a)
            for mod, p, w, a in zip(self.modules, params, wctx, acc)
        )

    def ensure_traced(self, params, x, side) -> None:
        jax.eval_shape(lambda p, xx, sd: self.fwd(p, xx, sd), params, x, side)


def loss_seed(loss: jax.Array) -> jax.Array:
    """Cotangent that seeds B at the loss position."""
    return jnp.ones_like(loss)
