"""Unified HBM-aware planning layer (DESIGN.md Sec. 6).

One ``plan()`` across every schedule family, under a true per-device HBM
budget.  The paper's automatic scheduler (Sec. 3) and the
controllable-memory follow-up (arXiv 2405.15362) both pick a schedule from
a model config and a *memory limit*; here that limit is the device's whole
HBM, itemized per device as

  * **params**    -- this stage's chunk parameters (pipe- and tp-sharded)
                     plus the replicated shared params (embedding, head);
  * **optim**     -- AdamW moments (fp32 m+v) under ZeRO-1 sharding over
                     the dp axis (``optim/sharding.py`` padding rules);
  * **act**       -- peak live F->B residual bytes (the paper's M_B term);
  * **wctx**      -- peak live B->W split-backward contexts (M_W);
  * **inbox**     -- the executor's collective-permute channel inboxes;
  * **sink**      -- head+loss residuals and contexts at the loss stage;
  * **xla_temp**  -- per-config fudge calibrated from a dryrun's
                     ``compiled.memory_analysis()``
                     (:meth:`ActivationByteModel.calibrate_from_dryrun`).

Two fidelities share one code path: the *model* fidelity prices act/wctx
with :class:`ActivationByteModel` and the inbox/sink with the compiled
plan's slot counts, needing no program; the *measured* fidelity reads the
tick executor's real buffer allocation (``PipelineExecutor.buffer_bytes``)
so feasibility is judged on the bytes the device will actually hold.

The candidate pool spans every schedule family in the repo -- 1F1B,
interleaved 1F1B, ZB-H1, ZB-H2, ZB-V, V-Min, V-Half, the Sec.-3.1
auto-greedy grid at the budget-implied limit, and the ``v_flex`` portfolio
(via ``auto.search(placement="v_flex")``).  Budget-implied searches are
cached cumulatively, so an ascending budget sweep keeps every cheaper plan
in the pool and the cost-vs-budget frontier is monotone.

``plan()`` results and the underlying ``v_flex`` portfolio are persisted
in the content-keyed on-disk cache (:mod:`repro.core.plan_cache`), so
cross-process sweeps replay instead of re-searching.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Callable, Dict, List, Optional, Tuple

from .memory import ActivationByteModel, memory_timeline
from .plan_cache import (
    PlanCache,
    default_cache,
    schedule_from_payload,
    schedule_to_payload,
    times_payload,
)
from .schedules.ir import Placement, Schedule, compile_plan
from .simulator import TimeModel, simulate

__all__ = [
    "HBMBreakdown",
    "PipelinePlan",
    "PlanReport",
    "HBMPlanner",
    "plan",
    "fastest_under_profile",
]

_INF = float("inf")

# beyond ~2p*M_B extra schedule memory buys no bubble (paper Sec. 5: ZB-2p
# is already ~zero bubble), so budget-implied search limits clamp there.
_LIMIT_CAP_FACTOR = 2.0


# --------------------------------------------------------------------- #
# itemized per-device HBM breakdown
# --------------------------------------------------------------------- #
@dataclasses.dataclass(frozen=True)
class HBMBreakdown:
    """Per-device bytes, itemized; ``total`` is the budget-facing sum."""

    params: float = 0.0
    optim: float = 0.0
    act: float = 0.0
    wctx: float = 0.0
    inbox: float = 0.0
    sink: float = 0.0
    xla_temp: float = 0.0

    def items(self) -> Dict[str, float]:
        return {
            "params": self.params,
            "optim": self.optim,
            "act": self.act,
            "wctx": self.wctx,
            "inbox": self.inbox,
            "sink": self.sink,
            "xla_temp": self.xla_temp,
        }

    @property
    def schedule_bytes(self) -> float:
        """The schedule-dependent share (everything but params/optim/temp)."""
        return self.act + self.wctx + self.inbox + self.sink

    @property
    def total(self) -> float:
        return sum(self.items().values())

    def binding_term(self) -> str:
        """Name of the largest term -- what a bigger budget must pay for."""
        return max(self.items().items(), key=lambda kv: kv[1])[0]

    def report(self, indent: str = "  ") -> str:
        lines = [
            f"{indent}{k:<8s} {v / 2**20:10.1f} MiB"
            for k, v in self.items().items()
            if v > 0
        ]
        lines.append(f"{indent}{'total':<8s} {self.total / 2**20:10.1f} MiB")
        return "\n".join(lines)


@dataclasses.dataclass
class PipelinePlan:
    """One evaluated candidate: schedule + byte model + cost + breakdown."""

    name: str
    schedule: Optional[Schedule]
    placement: Optional[Placement]
    byte_model: Optional[ActivationByteModel]
    cost: float
    bubble_rate: float
    breakdown: Optional[HBMBreakdown]
    fits: bool
    note: str = ""

    @property
    def total_bytes(self) -> float:
        return self.breakdown.total if self.breakdown is not None else _INF


@dataclasses.dataclass
class PlanReport:
    """``plan()``'s answer: the chosen plan or an itemized infeasibility."""

    budget_bytes: float
    feasible: bool
    chosen: Optional[PipelinePlan]
    plans: List[PipelinePlan]
    min_required_bytes: float
    from_cache: bool = False

    def summary(self) -> str:
        if self.feasible:
            c = self.chosen
            return (
                f"budget {self.budget_bytes / 2**20:.0f} MiB -> {c.name} "
                f"(cost {c.cost:.1f}, bubble {c.bubble_rate:.3f}, "
                f"{c.total_bytes / 2**20:.0f} MiB HBM)"
            )
        return (
            f"budget {self.budget_bytes / 2**20:.0f} MiB infeasible; "
            f"cheapest plan needs {self.min_required_bytes / 2**20:.0f} MiB"
        )

    def infeasibility_report(self) -> str:
        """Itemized report for the smallest-footprint plan, naming the
        binding term -- what the budget must grow (or the model shrink) by."""
        finite = [p for p in self.plans if p.schedule is not None]
        if not finite:
            return "no candidate schedule could be built"
        cheapest = min(finite, key=lambda p: p.total_bytes)
        bd = cheapest.breakdown
        short = cheapest.total_bytes - self.budget_bytes
        return (
            f"budget {self.budget_bytes / 2**20:.1f} MiB infeasible: "
            f"cheapest plan {cheapest.name} needs "
            f"{cheapest.total_bytes / 2**20:.1f} MiB "
            f"({short / 2**20:.1f} MiB short); binding term: "
            f"{bd.binding_term()}\n{bd.report()}"
        )


# --------------------------------------------------------------------- #
# parameter + optimizer byte accounting
# --------------------------------------------------------------------- #
def _strip_stage_axis(stacked):
    """Per-stage param shapes from the (p, ...)-stacked global tree."""
    import jax

    return tuple(
        jax.tree_util.tree_map(
            lambda l: jax.ShapeDtypeStruct(l.shape[1:], l.dtype), chunk
        )
        for chunk in stacked
    )


def _tree_bytes(tree) -> float:
    from .executor import PipelineExecutor

    # single source of truth for leaf-byte accounting (planner fixed-state
    # bytes must never drift from the executor's measured bytes)
    return float(PipelineExecutor._tree_bytes(tree))


def fixed_state_bytes(
    cfg, p: int, n_chunks: int, tp_size: int = 1, dp_size: int = 1
) -> Tuple[float, float]:
    """(param_bytes, optimizer_bytes) per device, from abstract init.

    Parameters are shape-evaluated through the real ``init_params`` (so
    padded groups, masks, and per-family extras are priced exactly), then
    pipe-sharded (one stage per device) and tp-sharded **per leaf** with
    the same name-based rules the runtime applies
    (``launch/sharding_rules.py``): column/row/expert/vocab-parallel
    leaves divide by tp, while replicated leaves (norm gains, routers,
    masks, ``lam``, ``*_rep`` projections when head counts do not divide
    tp) keep full bytes on every rank -- no uniform division.  Optimizer
    moments mirror each leaf's *local* shard and are then ZeRO-1 sharded
    over the dp axis with ``optim/sharding.py``'s padding rule.
    """
    import jax

    from ..launch.sharding_rules import tp_local_shapes
    from ..models.lm import RunSpec, init_params
    from ..optim.sharding import zero1_state_bytes

    spec = RunSpec(
        p=p, n_chunks=n_chunks, microbatch=1, seq_len=8, m=1, tp_size=tp_size
    )
    # any placement with the right chunk count works: init_params leaf
    # shapes depend only on (cfg, p, n_chunks); placement moves mask values
    # between stages, never changes a shape
    placement = (
        Placement.vshape(p) if n_chunks == 2 else Placement.linear(p, n_chunks)
    )
    stacked, shared = jax.eval_shape(lambda: init_params(cfg, spec, placement))
    per_stage = tuple(
        tp_local_shapes(chunk, tp_size) for chunk in _strip_stage_axis(stacked)
    )
    shared_local = tp_local_shapes(shared, tp_size)
    param_bytes = _tree_bytes(per_stage) + _tree_bytes(shared_local)
    optim_bytes = zero1_state_bytes(per_stage, dp_size) + zero1_state_bytes(
        shared_local, dp_size
    )
    return param_bytes, optim_bytes


# --------------------------------------------------------------------- #
# the planner
# --------------------------------------------------------------------- #
class HBMPlanner:
    """Search all schedule families under a per-device HBM byte budget.

    Stateful on purpose: the static family is evaluated once, and
    budget-implied searches (auto-greedy grid, v_flex portfolio) accumulate
    across ``plan()`` calls so an ascending budget sweep never loses a
    cheaper plan (monotone cost-vs-budget frontier).
    """

    def __init__(
        self,
        cfg,
        p: int,
        m: int,
        microbatch: int,
        seq_len: int,
        times: Optional[TimeModel] = None,
        tp_size: int = 1,
        dp_size: int = 1,
        measured: bool = False,
        xla_temp_bytes: Optional[float] = None,
        program_factory: Optional[Callable] = None,
    ):
        self.cfg = cfg
        self.p = p
        self.m = m
        self.microbatch = microbatch
        self.seq_len = seq_len
        self.times = times or TimeModel.unit()
        self.tp_size = tp_size
        self.dp_size = dp_size
        self.measured = measured
        self.program_factory = program_factory
        self.bytes_1c = ActivationByteModel.from_config(
            cfg, microbatch, seq_len, p, n_chunks=1, tp_size=tp_size
        )
        self.bytes_2c = ActivationByteModel.from_config(
            cfg, microbatch, seq_len, p, n_chunks=2, tp_size=tp_size
        )
        # None -> the checked-in per-config dryrun calibration the byte
        # model loaded (0 for uncalibrated archs)
        self.xla_temp_bytes = (
            float(xla_temp_bytes)
            if xla_temp_bytes is not None
            else float(self.bytes_1c.xla_temp_bytes)
        )
        self._static: Optional[List[PipelinePlan]] = None
        self._dynamic: Dict[str, PipelinePlan] = {}
        self._fixed: Dict[int, Tuple[float, float]] = {}
        self._programs: Dict[int, Tuple] = {}

    # -- fixed (schedule-independent) state ---------------------------- #
    def fixed_bytes(self, n_chunks: int) -> Tuple[float, float]:
        if n_chunks not in self._fixed:
            self._fixed[n_chunks] = fixed_state_bytes(
                self.cfg, self.p, n_chunks, self.tp_size, self.dp_size
            )
        return self._fixed[n_chunks]

    # -- measured fidelity: one abstract program per chunk count -------- #
    # Keyed on n_chunks alone on purpose: chunk modules (ChunkFBW), the
    # sink, and all buffer *shapes* depend only on (cfg, p, n_chunks) --
    # placement changes which stage holds which mask values, never a leaf
    # shape -- so a V-shape and a linear 2-chunk schedule price identically
    # and can share one abstract program.
    def _program(self, n_chunks: int, placement: Placement):
        if n_chunks not in self._programs:
            if self.program_factory is not None:
                self._programs[n_chunks] = self.program_factory(n_chunks)
            else:
                import jax

                from ..models.lm import (
                    RunSpec,
                    build_program,
                    init_params,
                    side_inputs,
                )

                spec = RunSpec(
                    p=self.p,
                    n_chunks=n_chunks,
                    microbatch=self.microbatch,
                    seq_len=self.seq_len,
                    m=self.m,
                    tp_size=self.tp_size,
                )
                prog = build_program(self.cfg, spec, placement)
                stacked, shared = jax.eval_shape(
                    lambda: init_params(self.cfg, spec, placement)
                )
                side = jax.eval_shape(lambda: side_inputs(self.cfg, spec))
                self._programs[n_chunks] = (
                    prog,
                    _strip_stage_axis(stacked),
                    shared,
                    side,
                )
        return self._programs[n_chunks]

    # -- analytic inbox/sink estimates (model fidelity) ------------------ #
    def _act_msg_bytes(self) -> float:
        cfg = self.cfg
        s_total = self.seq_len
        ex = cfg.extras_dict()
        if cfg.family == "encdec":
            s_total += ex["s_enc"]
        elif cfg.family == "vlm":
            s_total += ex["n_patches"]
        dtype_bytes = self.bytes_1c.dtype_bytes or 4
        return float(self.microbatch * s_total * cfg.d_model * dtype_bytes)

    def _sink_slot_bytes(self) -> Tuple[float, float]:
        """(sink residual, sink W-context) rough per-slot estimate: the
        normed activations plus tp-sharded logits at the loss position."""
        cfg = self.cfg
        tokens = self.microbatch * self.seq_len
        dtype_bytes = self.bytes_1c.dtype_bytes or 4
        res = tokens * (
            2 * cfg.d_model * dtype_bytes
            + cfg.vocab / max(1, self.tp_size) * dtype_bytes
        )
        wctx = tokens * 2 * cfg.d_model * dtype_bytes
        return float(res), float(wctx)

    # -- candidate evaluation -------------------------------------------- #
    def _evaluate(
        self,
        name: str,
        build: Callable[[], Schedule],
        n_chunks: int,
        grouped_w: bool = False,
        note: str = "",
    ) -> PipelinePlan:
        byte_model = self.bytes_1c if n_chunks == 1 else self.bytes_2c
        try:
            sched = build()
        except (ValueError, RuntimeError) as e:
            return PipelinePlan(
                name, None, None, byte_model, _INF, 1.0, None, False,
                note=f"build failed: {e}",
            )
        sched.name = name  # unique plan name (e.g. "zb-auto@8.0Mb"), not the
        # builder's internal default -- downstream consumers key on it
        times = (
            dataclasses.replace(self.times, grouped_w=True)
            if grouped_w
            else self.times
        )
        res = simulate(sched, times)
        params, optim = self.fixed_bytes(sched.n_chunks)
        ep = compile_plan(sched)
        if self.measured:
            from .executor import PipelineExecutor

            prog, sp, shared, side = self._program(
                sched.n_chunks, sched.placement
            )
            exe = PipelineExecutor(prog, ep, pipe_axis="pipe")
            bb = exe.buffer_bytes(sp, shared, side)
            act_b, wctx_b = bb["res"], bb["wctx"]
            inbox_b = bb["inbox"]
            sink_b = bb["sink"] + bb["sink_wctx"]
        else:
            tl = memory_timeline(sched, times, m_b=1.0, m_w=1.0)
            act_b = float(tl.peak_act.max()) * byte_model.m_b_bytes
            wctx_b = float(tl.peak_wctx.max()) * byte_model.m_w_bytes
            inbox_b = ep.inbox_slot_total() * self._act_msg_bytes()
            sink_res, sink_wctx = self._sink_slot_bytes()
            sink_b = (
                ep.n_sink_slots * sink_res + ep.n_sink_wctx_slots * sink_wctx
            )
        breakdown = HBMBreakdown(
            params=params,
            optim=optim,
            act=float(act_b),
            wctx=float(wctx_b),
            inbox=float(inbox_b),
            sink=float(sink_b),
            xla_temp=self.xla_temp_bytes,
        )
        return PipelinePlan(
            name=name,
            schedule=sched,
            placement=sched.placement,
            byte_model=byte_model,
            cost=res.cost,
            bubble_rate=res.bubble_rate,
            breakdown=breakdown,
            fits=True,  # byte-feasibility decided against a budget later
            note=note,
        )

    # -- family enumeration ---------------------------------------------- #
    def _static_plans(self) -> List[PipelinePlan]:
        from .schedules import (
            interleaved_1f1b,
            one_f_one_b,
            v_half,
            v_min,
            zb_h1,
            zb_h2,
            zb_v,
        )

        p, m = self.p, self.m
        if self._static is None:
            cands = [
                self._evaluate(
                    "1f1b", lambda: one_f_one_b(p, m), 1,
                    grouped_w=True, note="fused backward",
                ),
                self._evaluate("zb-h1", lambda: zb_h1(p, m), 1),
                self._evaluate("zb-h2", lambda: zb_h2(p, m), 1),
                self._evaluate(
                    "zb-v", lambda: zb_v(p, m, times=self.times), 2
                ),
                self._evaluate(
                    "v-half", lambda: v_half(p, m, times=self.times), 2
                ),
                self._evaluate(
                    "v-min", lambda: v_min(p, m, times=self.times), 2
                ),
            ]
            if m % p == 0:
                cands.append(
                    self._evaluate(
                        "1f1b-interleaved",
                        lambda: interleaved_1f1b(p, m, v=2),
                        2,
                        grouped_w=True,
                        note="fused backward",
                    )
                )
            self._static = cands
        return self._static

    def _budget_limit_units(self, budget_bytes: float, n_chunks: int) -> float:
        """Budget-implied schedule-memory limit in full-stage M_B units."""
        byte_model = self.bytes_1c if n_chunks == 1 else self.bytes_2c
        if byte_model.m_b_bytes <= 0:
            return 0.0
        params, optim = self.fixed_bytes(n_chunks)
        avail = budget_bytes - params - optim - self.xla_temp_bytes
        if not math.isfinite(avail):
            return _LIMIT_CAP_FACTOR * self.p
        limit = round(avail / byte_model.m_b_bytes, 1)
        return min(limit, _LIMIT_CAP_FACTOR * self.p)

    def _seed_one_search(
        self, budget_bytes: float, n_chunks: int, prefix: str, placement, note: str
    ) -> None:
        """Seed a budget-implied search, tightening the limit when needed.

        The first limit only discounts the schedule-independent terms
        (params/optim/temp); inbox + sink bytes depend on the schedule, so
        when the seeded candidate overshoots the budget the limit is
        re-derived with that candidate's actual overhead and the search
        re-run tighter (bounded retries) -- otherwise a feasible plan just
        inside the boundary would be missed and the budget misreported as
        infeasible.
        """
        from .schedules import search

        p, m = self.p, self.m
        byte_model = self.bytes_1c if n_chunks == 1 else self.bytes_2c
        lim = self._budget_limit_units(budget_bytes, n_chunks)
        for _ in range(3):
            if lim < 1.0:
                return
            name = f"{prefix}@{lim:.1f}Mb"
            if name not in self._dynamic:
                lim_now = lim
                self._dynamic[name] = self._evaluate(
                    name,
                    lambda: search(
                        p, m, self.times, m_limit=lim_now, placement=placement
                    ).schedule,
                    n_chunks,
                    note=note,
                )
            cand = self._dynamic[name]
            if cand.schedule is None or cand.total_bytes <= budget_bytes:
                return
            if byte_model.m_b_bytes <= 0 or not math.isfinite(budget_bytes):
                return
            overhead = cand.total_bytes - cand.breakdown.act
            retry = round(
                (budget_bytes - overhead) / byte_model.m_b_bytes - 0.05, 1
            )
            if retry >= lim:  # no progress possible
                return
            lim = retry

    def _seed_budget_searches(self, budget_bytes: float) -> None:
        self._seed_one_search(
            budget_bytes, 1, "zb-auto", None,
            note="Sec.-3.1 heuristic at the budget-implied limit",
        )
        self._seed_one_search(
            budget_bytes, 2, "v-flex", "v_flex",
            note="v_flex portfolio at the budget-implied limit",
        )

    def candidates(self, budget_bytes: Optional[float] = None) -> List[PipelinePlan]:
        """The full family (cached) plus cumulative budget-tuned searches."""
        if budget_bytes is not None:
            self._seed_budget_searches(budget_bytes)
        return list(self._static_plans()) + list(self._dynamic.values())

    # -- the decision ----------------------------------------------------- #
    def plan(self, budget_bytes: float) -> PlanReport:
        plans = []
        for c in self.candidates(budget_bytes):
            if c.schedule is None:
                plans.append(c)
                continue
            plans.append(
                dataclasses.replace(c, fits=c.total_bytes <= budget_bytes)
            )
        feasible = [c for c in plans if c.fits and c.schedule is not None]
        finite = [c for c in plans if c.schedule is not None]
        min_required = min((c.total_bytes for c in finite), default=_INF)
        if not feasible:
            return PlanReport(
                budget_bytes=budget_bytes,
                feasible=False,
                chosen=None,
                plans=plans,
                min_required_bytes=min_required,
            )
        best = min(feasible, key=lambda c: (c.cost, c.total_bytes))
        return PlanReport(
            budget_bytes=budget_bytes,
            feasible=True,
            chosen=best,
            plans=plans,
            min_required_bytes=min_required,
        )


# --------------------------------------------------------------------- #
# the single entry point, disk-cached
# --------------------------------------------------------------------- #
def _plan_payload(p: PipelinePlan) -> Dict[str, Any]:
    d = {
        "name": p.name,
        "cost": p.cost,
        "bubble_rate": p.bubble_rate,
        "fits": p.fits,
        "note": p.note,
        "schedule": (
            schedule_to_payload(p.schedule) if p.schedule is not None else None
        ),
        "breakdown": p.breakdown.items() if p.breakdown is not None else None,
    }
    if p.byte_model is not None:
        d["unit_bytes"] = [p.byte_model.m_b_bytes, p.byte_model.m_w_bytes]
    return d


def _plan_from_payload(d: Dict[str, Any]) -> PipelinePlan:
    sched = (
        schedule_from_payload(d["schedule"]) if d.get("schedule") else None
    )
    bd = HBMBreakdown(**d["breakdown"]) if d.get("breakdown") else None
    bm = None
    if d.get("unit_bytes"):
        bm = ActivationByteModel.from_measured(*d["unit_bytes"])
    return PipelinePlan(
        name=d["name"],
        schedule=sched,
        placement=sched.placement if sched is not None else None,
        byte_model=bm,
        cost=d["cost"],
        bubble_rate=d["bubble_rate"],
        breakdown=bd,
        fits=d["fits"],
        note=d.get("note", ""),
    )


def _report_to_payload(r: PlanReport) -> Dict[str, Any]:
    return {
        "budget_bytes": (
            r.budget_bytes if math.isfinite(r.budget_bytes) else None
        ),
        "feasible": r.feasible,
        "min_required_bytes": r.min_required_bytes,
        "chosen": _plan_payload(r.chosen) if r.chosen is not None else None,
        "plans": [_plan_payload(p) for p in r.plans],
    }


def _report_from_payload(d: Dict[str, Any]) -> PlanReport:
    chosen = _plan_from_payload(d["chosen"]) if d.get("chosen") else None
    return PlanReport(
        budget_bytes=(
            d["budget_bytes"] if d.get("budget_bytes") is not None else _INF
        ),
        feasible=d["feasible"],
        chosen=chosen,
        plans=[_plan_from_payload(p) for p in d.get("plans", [])],
        min_required_bytes=d["min_required_bytes"],
        from_cache=True,
    )


def plan(
    config,
    p: int,
    m: int,
    times: Optional[TimeModel] = None,
    hbm_budget_bytes: float = _INF,
    *,
    microbatch: int = 1,
    seq_len: int = 2048,
    tp_size: int = 1,
    dp_size: int = 1,
    measured: bool = False,
    xla_temp_bytes: Optional[float] = None,
    cache: Optional[PlanCache] = None,
    use_cache: bool = True,
) -> PlanReport:
    """Pick the fastest schedule (across every family) that fits the budget.

    Returns a :class:`PlanReport`; on infeasibility ``report.feasible`` is
    False and ``report.infeasibility_report()`` itemizes the cheapest
    plan's HBM breakdown, naming the binding term.  Results are persisted
    in the content-keyed on-disk plan cache (key: config content, run
    shape, times, budget, fidelity) so a repeated sweep -- even from a
    fresh process -- replays the stored plan.

    For budget *sweeps* prefer one :class:`HBMPlanner` and call its
    ``.plan`` per point: the planner's cumulative search pool guarantees a
    monotone cost-vs-budget frontier.
    """
    times = times or TimeModel.unit()
    if xla_temp_bytes is None:
        # the checked-in dryrun calibration, scaled to this run shape (the
        # same resolution HBMPlanner applies; resolved here so the cache
        # key reflects the charged value)
        xla_temp_bytes = ActivationByteModel.from_config(
            config, microbatch, seq_len, p, n_chunks=1, tp_size=tp_size
        ).xla_temp_bytes
    if cache is None:
        cache = default_cache() if use_cache else PlanCache(None, enabled=False)
    key = cache.key(
        "plan",
        cfg=config,
        p=p,
        m=m,
        microbatch=microbatch,
        seq_len=seq_len,
        tp=tp_size,
        dp=dp_size,
        measured=measured,
        xla_temp=xla_temp_bytes,
        times=times_payload(times),
        budget=hbm_budget_bytes,
    )
    hit = cache.get(key)
    if hit is not None:
        return _report_from_payload(hit)
    planner = HBMPlanner(
        config,
        p=p,
        m=m,
        microbatch=microbatch,
        seq_len=seq_len,
        times=times,
        tp_size=tp_size,
        dp_size=dp_size,
        measured=measured,
        xla_temp_bytes=xla_temp_bytes,
    )
    report = planner.plan(hbm_budget_bytes)
    cache.put(key, _report_to_payload(report))
    return report


# --------------------------------------------------------------------- #
# unit-space family search (straggler replanning)
# --------------------------------------------------------------------- #
def fastest_under_profile(
    p: int,
    m: int,
    times: TimeModel,
    m_limit: float,
    m_b: float = 1.0,
    m_w: float = 0.5,
) -> Tuple[Schedule, float]:
    """Cheapest schedule across all families under a unit memory limit.

    The byte-free counterpart of :meth:`HBMPlanner.plan` used by the
    runtime's straggler replanning: the limit is in (M_B, M_W) units and
    candidates are filtered by the op-count memory profile, the same
    convention as ``auto.search``.  Returns (schedule, simulated cost).

    Two searches cover every family: the linear-placement grid (which
    already folds in the handcrafted ZB-H1/H2 portfolio) and the V-shape
    grid with the ``v_flex`` portfolio (which folds in handcrafted ZB-V
    and the stable V-Min/V-Half patterns) -- re-building V-Min/V-Half
    separately would only repeat portfolio members under the same limit.
    """
    from .schedules import search

    best: Optional[Tuple[float, Schedule]] = None

    def consider(sched: Schedule) -> None:
        nonlocal best
        C = sched.n_chunks
        peak = sched.memory_profile(m_b / C, m_w / C).max_peak
        if peak > m_limit + 1e-9:
            return
        try:
            cost = simulate(sched, times).cost
        except (ValueError, RuntimeError):
            return
        if best is None or cost < best[0]:
            best = (cost, sched)

    for placement in (None, "v_flex"):
        try:
            consider(
                search(
                    p, m, times, m_limit=m_limit, m_b=m_b, m_w=m_w,
                    placement=placement,
                ).schedule
            )
        except RuntimeError:
            pass
    if best is None:
        raise RuntimeError(
            f"no schedule fits the unit memory limit {m_limit} (p={p}, m={m})"
        )
    return best[1], best[0]
