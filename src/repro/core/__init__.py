from . import passes, simulator
from .executor import PipelineExecutor, PipelineProgram

__all__ = ["passes", "simulator", "PipelineExecutor", "PipelineProgram"]
