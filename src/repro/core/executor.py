"""SPMD ticked pipeline executor (shard_map over the pipe axis).

Runs any :class:`ExecutionPlan` -- 1F1B, ZB-H1/H2, ZB-V, interleaved,
auto-searched -- as one SPMD program:

  * time is quantized into *ticks*; at tick t every stage looks up its op in
    the static ``(p, T)`` tables compiled from the schedule and
    ``lax.switch``es into the F / B / W / idle branch for the op's chunk
    (generic modes) -- or, in the ``specialized`` mode, each tick is traced
    against its host-constant table column: direct branch calls, per-tick
    constant folding, and a steady-state scan superstep (DESIGN.md Sec. 8);
  * activations and activation-gradients cross stages through four
    collective-permute channels (F-up, F-down, B-down, B-up), closed once per
    tick *outside* the switch (pipe-axis collectives must be unconditional
    under SPMD); channels a schedule never uses are pruned at trace time,
    and the specialized mode emits a permute only on (tick, channel) pairs
    where the plan actually communicates, with exact sender/receiver edges;
  * per-stage state lives in slot-addressed buffers whose sizes come from the
    plan's interval analysis: activation/gradient inboxes, residuals (F->B,
    freed when B completes -- the paper's accounting), weight-grad contexts
    (B->W; the byte-minimal M_W context of the compact split, including any
    stacked per-step scan contexts -- DESIGN.md Sec. 7), and the
    head+loss residuals/contexts at the loss position.  When the chunks'
    buffer structures agree (the uniform-group SPMD case), residual and
    W-context pools are shared across chunks, so the per-device footprint is
    the plan's joint cross-chunk peak, not the sum of per-chunk peaks.

SPMD invariant: collectives over the *tensor-parallel* axis may appear inside
switch branches (all ranks of a TP group share the stage index and therefore
the branch); collectives over the *pipe* axis must stay outside.  See
DESIGN.md Sec. 3.
"""

from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map

from .passes import FBWModule
from .schedules.ir import (
    CHANNEL_BWD_DOWN,
    CHANNEL_BWD_UP,
    CHANNEL_FWD_DOWN,
    CHANNEL_FWD_UP,
    ExecutionPlan,
    N_CHANNELS,
    OpKind,
)

PyTree = Any

__all__ = ["PipelineProgram", "PipelineExecutor", "microbatch_split"]

_CHANNEL_SHIFT = {
    CHANNEL_FWD_UP: +1,
    CHANNEL_FWD_DOWN: -1,
    CHANNEL_BWD_DOWN: -1,
    CHANNEL_BWD_UP: +1,
}


@dataclasses.dataclass
class PipelineProgram:
    """What the model hands the executor.

    ``chunks[c]`` is the FBW module computing chunk ``c``'s layer group on one
    stage (structurally identical across stages; parameters differ).  ``src``
    produces the chunk-0 input from the per-microbatch side inputs (embedding
    or modality-frontend stub); ``sink`` maps the last chunk's output + side
    inputs to the scalar loss (final norm + LM head + CE).  Shared parameters
    (embedding table, head) are replicated along the pipe axis and their
    gradients psum'd over it.
    """

    chunks: Sequence[FBWModule]
    src_fwd: Callable[[PyTree, PyTree], jax.Array]  # (shared, side_mb) -> x
    src_bwd_w: Callable[[PyTree, PyTree, jax.Array], PyTree]  # -> shared grads
    sink: FBWModule  # fwd(shared, y, side_mb) -> loss; auto_fbw-split
    act_shape: Tuple[int, ...]  # (b_mb, s, h) carried between stages
    act_dtype: Any = jnp.float32

    def n_chunks(self) -> int:
        return len(self.chunks)


def microbatch_split(batch: PyTree, m: int) -> PyTree:
    """(G, ...) -> (m, G/m, ...) microbatch axis up front."""
    def split(x):
        g = x.shape[0]
        assert g % m == 0, f"batch {g} not divisible by m={m}"
        return x.reshape((m, g // m) + x.shape[1:])

    return jax.tree_util.tree_map(split, batch)


def _dyn_get(buf: jax.Array, idx: jax.Array) -> jax.Array:
    """buf[(idx, ...)] with a traced index."""
    return jax.lax.dynamic_index_in_dim(buf, idx, 0, keepdims=False)


def _dyn_set(buf: jax.Array, idx: jax.Array, val: jax.Array) -> jax.Array:
    return jax.lax.dynamic_update_index_in_dim(buf, val, idx, 0)


def _masked_set(buf, idx, val, active):
    """In-place slot write that keeps the old value when inactive.

    ``active`` may be a Python/numpy bool (the specialized executor bakes
    per-tick constants): True folds to a plain slot write, False to a
    no-op, so statically-dead writes never reach XLA.
    """
    if isinstance(active, (bool, np.bool_)):
        if not active:
            return buf
        return _dyn_set(buf, idx, val.astype(buf.dtype))
    old = _dyn_get(buf, idx)
    act = jnp.asarray(active)
    sel = jnp.where(
        act.reshape((1,) * val.ndim) if val.ndim else act, val, old
    ).astype(buf.dtype)
    return _dyn_set(buf, idx, sel)


def _maybe_cond(pred, true_fn, false_fn, operand):
    """``lax.cond`` that folds at trace time on a host-constant predicate.

    The branch bodies are written once and reused by both executor modes;
    under specialization the per-tick flags arrive as Python bools and the
    untaken side must not be traced at all (it may index buffers that the
    plan proves dead at this tick).
    """
    if isinstance(pred, (bool, np.bool_)):
        return true_fn(operand) if pred else false_fn(operand)
    return jax.lax.cond(pred, true_fn, false_fn, operand)


def _tree_dyn_get(bufs: PyTree, idx) -> PyTree:
    return jax.tree_util.tree_map(lambda b: _dyn_get(b, idx), bufs)


def _tree_dyn_set(bufs: PyTree, idx, vals: PyTree) -> PyTree:
    return jax.tree_util.tree_map(lambda b, v: _dyn_set(b, idx, v.astype(b.dtype)), bufs, vals)


def _zeros_buffer(shape_dtype: jax.ShapeDtypeStruct, slots: int) -> jax.Array:
    return jnp.zeros((slots,) + tuple(shape_dtype.shape), shape_dtype.dtype)


class PipelineExecutor:
    """Compiles (program, plan) into a pipelined grads-and-loss function.

    The returned ``grad_fn(stage_params, shared, batch_side) -> (grads,
    shared_grads, loss)`` is pure and shard_map-compatible: it must run inside
    a shard_map whose ``axis_name == pipe_axis``; ``stage_params`` are this
    stage's (already-local) parameters.
    """

    def __init__(
        self,
        program: PipelineProgram,
        plan: ExecutionPlan,
        pipe_axis: str = "data",
        unroll: bool = False,
        prune_channels: bool = True,
        tp_axis: Optional[str] = None,
        shard_channels: bool = False,
        fuse_wgrad: bool = True,
        tp_size: Optional[int] = None,
        mode: Optional[str] = None,
        steady_scan: bool = True,
    ):
        if program.n_chunks() != plan.n_chunks:
            raise ValueError(
                f"program has {program.n_chunks()} chunks, plan {plan.n_chunks}"
            )
        self.fuse_wgrad = fuse_wgrad
        self.program = program
        self.plan = plan
        self.pipe_axis = pipe_axis
        self.unroll = unroll
        # Compilation mode (DESIGN.md Sec. 8):
        #   "scan"        -- one generic tick body inside lax.scan; every tick
        #                    pays the full switch + all live channels;
        #   "unroll"      -- the generic tick unrolled (legacy unroll=True);
        #   "specialized" -- each tick traced against its host-constant plan
        #                    column: direct branch calls, exact-edge permutes
        #                    only where the plan communicates, and the steady
        #                    window compiled once inside a scan superstep.
        if mode is None:
            mode = "unroll" if unroll else "scan"
        if mode not in ("scan", "unroll", "specialized"):
            raise ValueError(f"unknown executor mode {mode!r}")
        self.mode = mode
        self.steady_scan = steady_scan
        self.channels = (
            plan.used_channels() if prune_channels else tuple(range(N_CHANNELS))
        )
        # Sequence-sharded channels (beyond-paper, EXPERIMENTS.md Perf):
        # every TP rank otherwise permutes a redundant full activation copy
        # over the (slow) pipe links; instead each rank sends its 1/tp seq
        # slice and the consumer all-gathers over the (fast) TP links.
        self.tp_axis = tp_axis
        self.shard_channels = bool(shard_channels and tp_axis is not None)
        # static TP degree hint for *byte accounting only*: the runtime
        # channel shape divides seq by psum(1, tp_axis) at trace time, which
        # abstract sizing cannot see (buffer_bytes / channel_message_bytes)
        self.tp_size = tp_size

    # ------------------------------------------------------------------ #
    def _abstract_state(self, stage_params, shared, side_all):
        """Shape-evaluate chunk/sink residual structures to size the buffers."""
        prog, plan = self.program, self.plan
        side_mb = jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), side_all
        )
        act = jax.ShapeDtypeStruct(prog.act_shape, prog.act_dtype)

        res_shapes, wctx_shapes = [], []
        y_shape = act
        for c, mod in enumerate(prog.chunks):
            fwd_out = jax.eval_shape(
                lambda p, x, sd: mod.fwd(p, x, sd), stage_params[c], act, side_mb
            )
            y_shape, res_shape = fwd_out
            res_shapes.append(res_shape)
            dy = act
            bwd_out = jax.eval_shape(
                lambda p, r, g, sd: mod.bwd_x(p, r, g, sd),
                stage_params[c],
                res_shape,
                dy,
                side_mb,
            )
            _, wctx_shape = bwd_out
            wctx_shapes.append(wctx_shape)

        sink_out = jax.eval_shape(
            lambda sh, y, sd: prog.sink.fwd(sh, y, sd), shared, act, side_mb
        )
        loss_shape, sink_res_shape = sink_out
        ones = jax.ShapeDtypeStruct(loss_shape.shape, loss_shape.dtype)
        _, sink_wctx_shape = jax.eval_shape(
            lambda sh, r, g, sd: prog.sink.bwd_x(sh, r, g, sd),
            shared,
            sink_res_shape,
            ones,
            side_mb,
        )
        return res_shapes, wctx_shapes, sink_res_shape, sink_wctx_shape, loss_shape

    @staticmethod
    def _uniform(shapes) -> bool:
        """True when every chunk's buffer pytree has identical structure."""
        sig = [
            (
                jax.tree_util.tree_structure(sh),
                tuple(
                    (tuple(l.shape), jnp.dtype(l.dtype).name)
                    for l in jax.tree_util.tree_leaves(sh)
                ),
            )
            for sh in shapes
        ]
        return all(s == sig[0] for s in sig)

    # ------------------------------------------------------------------ #
    # measured buffer accounting (what the tick executor actually allocates)
    # ------------------------------------------------------------------ #
    @staticmethod
    def _tree_bytes(sh) -> int:
        return int(
            sum(
                int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
                for l in jax.tree_util.tree_leaves(sh)
            )
        )

    def state_shapes(self, stage_params, shared, side_all):
        """Abstract buffer state: per-slot structures + slot counts.

        ``stage_params`` / ``shared`` / ``side_all`` may be real arrays or
        ``ShapeDtypeStruct`` pytrees; nothing is computed.
        """
        plan = self.plan
        res_sh, wctx_sh, sink_sh, sink_wctx_sh, loss_sh = self._abstract_state(
            stage_params, shared, side_all
        )
        share_res = self._uniform(res_sh)
        share_wctx = self._uniform(wctx_sh)
        return dict(
            res=res_sh,
            wctx=wctx_sh,
            sink=sink_sh,
            sink_wctx=sink_wctx_sh,
            loss=loss_sh,
            share_res=share_res,
            share_wctx=share_wctx,
            n_res_slots=(
                (plan.n_res_slots_joint,) if share_res else plan.n_res_slots
            ),
            n_wctx_slots=(
                (plan.n_wctx_slots_joint,) if share_wctx else plan.n_wctx_slots
            ),
            n_sink_slots=plan.n_sink_slots,
            n_sink_wctx_slots=plan.n_sink_wctx_slots,
        )

    def channel_message_bytes(self) -> float:
        """Bytes of one inbox slot (one inter-stage message).

        With ``shard_channels`` each rank carries only its 1/tp seq slice;
        that division is exact when the constructor got the static
        ``tp_size`` hint, otherwise the *unsharded* shape is returned --
        an upper bound, so byte-budget feasibility errs conservative.
        """
        full = int(np.prod(self.program.act_shape)) * jnp.dtype(
            self.program.act_dtype
        ).itemsize
        if self.shard_channels and self.tp_size:
            return float(full) / self.tp_size
        return float(full)

    def buffer_bytes(self, stage_params, shared, side_all):
        """Bytes the executor allocates per device, by buffer family.

        These are the *measured* numbers the analytic byte model is checked
        against (tests/test_measured_memory.py): slot-addressed pools are
        sized by the plan's interval analysis, so total allocation equals the
        peak of live bytes over the run (greedy interval coloring is optimal
        on interval graphs).
        """
        plan = self.plan
        st = self.state_shapes(stage_params, shared, side_all)
        res_sh, wctx_sh = st["res"], st["wctx"]
        res_slot_bytes = [self._tree_bytes(sh) for sh in res_sh]
        wctx_slot_bytes = [self._tree_bytes(sh) for sh in wctx_sh]
        if st["share_res"]:
            res_total = plan.n_res_slots_joint * res_slot_bytes[0]
        else:
            res_total = sum(
                n * b for n, b in zip(plan.n_res_slots, res_slot_bytes)
            )
        if st["share_wctx"]:
            wctx_total = plan.n_wctx_slots_joint * wctx_slot_bytes[0]
        else:
            wctx_total = sum(
                n * b for n, b in zip(plan.n_wctx_slots, wctx_slot_bytes)
            )
        # flat (C, max-slots) inbox buffers: see ExecutionPlan.inbox_slot_total
        inbox_total = plan.inbox_slot_total() * self.channel_message_bytes()
        sink_total = plan.n_sink_slots * self._tree_bytes(st["sink"])
        sink_wctx_total = plan.n_sink_wctx_slots * self._tree_bytes(
            st["sink_wctx"]
        )
        # per-block W-context bytes (one entry per block of each chunk, when
        # the chunk module exposes a per-block context tuple -- ChunkFBW
        # does).  Stacked scan-split contexts are ordinary leaves here; this
        # is the number the recurrent-split acceptance measures.
        wctx_block_bytes = tuple(
            tuple(self._tree_bytes(blk) for blk in sh)
            if isinstance(sh, (tuple, list))
            else (self._tree_bytes(sh),)
            for sh in wctx_sh
        )
        return dict(
            res=float(res_total),
            wctx=float(wctx_total),
            inbox=float(inbox_total),
            sink=float(sink_total),
            sink_wctx=float(sink_wctx_total),
            total=float(
                res_total + wctx_total + inbox_total + sink_total
                + sink_wctx_total
            ),
            res_slot_bytes=tuple(float(b) for b in res_slot_bytes),
            wctx_slot_bytes=tuple(float(b) for b in wctx_slot_bytes),
            wctx_block_bytes=wctx_block_bytes,
        )

    # ------------------------------------------------------------------ #
    def build_grad_fn(self):
        prog, plan = self.program, self.plan
        C = plan.n_chunks
        act_sd = jax.ShapeDtypeStruct(prog.act_shape, prog.act_dtype)

        def grad_fn(stage_params, shared, side_all):
            # -- static residual structures -------------------------------- #
            res_sh, wctx_sh, sink_sh, sink_wctx_sh, loss_sh = (
                self._abstract_state(stage_params, shared, side_all)
            )
            share_res = self._uniform(res_sh)
            share_wctx = self._uniform(wctx_sh)

            # -- stage index (tick tables are gathered per mode below) ------ #
            sidx = jax.lax.axis_index(self.pipe_axis)

            # -- buffers ----------------------------------------------------- #
            S_act = max(plan.n_act_slots)
            S_grad = max(plan.n_grad_slots)
            if self.shard_channels:
                tp_size = jax.lax.psum(1, self.tp_axis)
                assert prog.act_shape[1] % tp_size == 0, (
                    f"seq {prog.act_shape[1]} must divide tp={tp_size} for"
                    " sequence-sharded channels"
                )
                chan_shape = (
                    prog.act_shape[0],
                    prog.act_shape[1] // tp_size,
                ) + prog.act_shape[2:]
            else:
                chan_shape = prog.act_shape
            act_in = jnp.zeros((C, S_act) + chan_shape, prog.act_dtype)
            grad_in = jnp.zeros((C, S_grad) + chan_shape, prog.act_dtype)

            def to_chan(full):
                """Slice this rank's seq shard for the channel payload."""
                if not self.shard_channels:
                    return full
                r = jax.lax.axis_index(self.tp_axis)
                k = chan_shape[1]
                return jax.lax.dynamic_slice_in_dim(full, r * k, k, axis=1)

            def from_chan(slice_):
                """Reassemble the full activation from seq shards."""
                if not self.shard_channels:
                    return slice_
                return jax.lax.all_gather(
                    slice_, self.tp_axis, axis=1, tiled=True
                )
            # Residual / W-context pools.  Shared across chunks (joint slot
            # ids) when every chunk's buffer structure matches; per-chunk
            # pools otherwise.  Residual slots are live [F, B] only: B's true
            # split-VJP leaves nothing for W to rebuild.
            if share_res:
                res_buf = jax.tree_util.tree_map(
                    lambda sd: _zeros_buffer(sd, plan.n_res_slots_joint),
                    res_sh[0],
                )
            else:
                res_buf = [
                    jax.tree_util.tree_map(
                        lambda sd: _zeros_buffer(sd, plan.n_res_slots[c]),
                        res_sh[c],
                    )
                    for c in range(C)
                ]
            if share_wctx:
                wctx_buf = jax.tree_util.tree_map(
                    lambda sd: _zeros_buffer(sd, plan.n_wctx_slots_joint),
                    wctx_sh[0],
                )
            else:
                wctx_buf = [
                    jax.tree_util.tree_map(
                        lambda sd: _zeros_buffer(sd, plan.n_wctx_slots[c]),
                        wctx_sh[c],
                    )
                    for c in range(C)
                ]
            sink_buf = jax.tree_util.tree_map(
                lambda sd: _zeros_buffer(sd, plan.n_sink_slots), sink_sh
            )
            sink_wctx_buf = jax.tree_util.tree_map(
                lambda sd: _zeros_buffer(sd, plan.n_sink_wctx_slots),
                sink_wctx_sh,
            )

            def pool_get(buf, shared_pool, c, idx):
                return _tree_dyn_get(buf if shared_pool else buf[c], idx)

            def pool_set(buf, shared_pool, c, idx, vals):
                if shared_pool:
                    return _tree_dyn_set(buf, idx, vals)
                lst = list(buf)
                lst[c] = _tree_dyn_set(lst[c], idx, vals)
                return lst
            acc_dt = lambda leaf: jnp.promote_types(leaf.dtype, jnp.float32)
            grad_acc = jax.tree_util.tree_map(
                lambda pleaf: jnp.zeros(pleaf.shape, acc_dt(pleaf)), stage_params
            )
            shared_acc = jax.tree_util.tree_map(
                lambda pleaf: jnp.zeros(pleaf.shape, acc_dt(pleaf)), shared
            )
            loss_acc = jnp.zeros((), jnp.promote_types(loss_sh.dtype, jnp.float32))

            state0 = dict(
                act_in=act_in,
                grad_in=grad_in,
                res=res_buf,
                wctx=wctx_buf,
                sink=sink_buf,
                sink_wctx=sink_wctx_buf,
                grad_acc=grad_acc,
                shared_acc=shared_acc,
                loss=loss_acc,
            )

            zero_act = jnp.zeros(prog.act_shape, prog.act_dtype)

            # -- branch bodies ---------------------------------------------- #
            def side_at(mb):
                return jax.tree_util.tree_map(
                    lambda a: _dyn_get(a, mb), side_all
                )

            def f_branch(c):
                def body(state, t):
                    side_mb = side_at(t["mb"])
                    x_inbox = from_chan(_dyn_get(state["act_in"][c], t["in_slot"]))

                    def from_src(_):
                        return prog.src_fwd(shared, side_mb).astype(prog.act_dtype)

                    x = _maybe_cond(
                        t["is_src"], from_src, lambda _: x_inbox, None
                    )
                    y, res = prog.chunks[c].fwd(stage_params[c], x, side_mb)
                    state = dict(state)
                    state["res"] = pool_set(
                        state["res"], share_res, c, t["res_slot"], res
                    )

                    def with_loss(st):
                        loss, sres = prog.sink.fwd(shared, y, side_mb)
                        st = dict(st)
                        st["sink"] = jax.tree_util.tree_map(
                            lambda b, v: _masked_set(b, t["sink_slot"], v, True),
                            st["sink"],
                            sres,
                        )
                        st["loss"] = st["loss"] + loss.astype(st["loss"].dtype)
                        return st

                    state = _maybe_cond(
                        t["is_loss"], with_loss, lambda st: st, state
                    )
                    return state, y.astype(prog.act_dtype)

                return body

            def b_branch(c):
                def body(state, t):
                    side_mb = side_at(t["mb"])
                    res = pool_get(state["res"], share_res, c, t["res_slot"])
                    dy_inbox = from_chan(
                        _dyn_get(state["grad_in"][c], t["in_slot"])
                    )
                    state = dict(state)

                    if c == C - 1:
                        def from_sink(_):
                            sres = _tree_dyn_get(state["sink"], t["sink_slot"])
                            ones = jnp.ones(loss_sh.shape, loss_sh.dtype)
                            dy_s, swctx = prog.sink.bwd_x(
                                shared, sres, ones, side_mb
                            )
                            return dy_s.astype(prog.act_dtype), swctx

                        def from_inbox(_):
                            zeros = jax.tree_util.tree_map(
                                lambda sd: jnp.zeros(sd.shape, sd.dtype),
                                sink_wctx_sh,
                            )
                            return dy_inbox, zeros

                        dy, swctx_val = _maybe_cond(
                            t["is_loss"], from_sink, from_inbox, None
                        )
                        state["sink_wctx"] = jax.tree_util.tree_map(
                            lambda b, v: _masked_set(
                                b, t["sink_wctx_slot"], v, t["is_loss"]
                            ),
                            state["sink_wctx"],
                            swctx_val,
                        )
                    else:
                        dy = dy_inbox

                    # True input-gradient VJP: emits the compact M_W context
                    # (the byte-minimal cut; for split recurrences a stacked
                    # per-step context); the residual slot is dead after
                    # this tick and the interval analysis reuses it.
                    dx, wctx = prog.chunks[c].bwd_x(
                        stage_params[c], res, dy, side_mb
                    )
                    state["wctx"] = pool_set(
                        state["wctx"], share_wctx, c, t["wctx_slot"], wctx
                    )

                    if c == 0:
                        def embed_grads(st):
                            g = prog.src_bwd_w(shared, side_mb, dx)
                            st = dict(st)
                            st["shared_acc"] = jax.tree_util.tree_map(
                                lambda a, b: a + b.astype(a.dtype),
                                st["shared_acc"],
                                g,
                            )
                            return st

                        state = _maybe_cond(
                            t["is_last_b"], embed_grads, lambda st: st, state
                        )
                    return state, dx.astype(prog.act_dtype)

                return body

            def w_branch(c):
                def body(state, t):
                    side_mb = side_at(t["mb"])
                    # W consumes only the M_W context -- no residuals, no
                    # pullback rebuild.  Terminal dW = a^T @ g products are
                    # fused into the accumulator via kernels/wgrad_accum.
                    wctx = pool_get(state["wctx"], share_wctx, c, t["wctx_slot"])
                    state = dict(state)
                    acc = list(state["grad_acc"])
                    if self.fuse_wgrad:
                        acc[c] = prog.chunks[c].bwd_w(
                            stage_params[c], wctx, side_mb, acc=acc[c]
                        )
                    else:
                        g = prog.chunks[c].bwd_w(stage_params[c], wctx, side_mb)
                        acc[c] = jax.tree_util.tree_map(
                            lambda a, b: a + b.astype(a.dtype), acc[c], g
                        )
                    state["grad_acc"] = type(state["grad_acc"])(acc)

                    if c == C - 1:
                        def sink_grads(st):
                            swctx = _tree_dyn_get(
                                st["sink_wctx"], t["sink_wctx_slot"]
                            )
                            sg = prog.sink.bwd_w(shared, swctx, side_mb)
                            st = dict(st)
                            st["shared_acc"] = jax.tree_util.tree_map(
                                lambda a, b: a + b.astype(a.dtype),
                                st["shared_acc"],
                                sg,
                            )
                            return st

                        state = _maybe_cond(
                            t["is_loss"], sink_grads, lambda st: st, state
                        )
                    return state, zero_act

                return body

            def idle_branch(state, t):
                return state, zero_act

            branches = [idle_branch]
            for c in range(C):
                branches.append(f_branch(c))
            for c in range(C):
                branches.append(b_branch(c))
            for c in range(C):
                branches.append(w_branch(c))

            def branch_index(kind, chunk):
                # idle=0; F: 1+c; B: 1+C+c; W: 1+2C+c
                base = jnp.where(
                    kind == int(OpKind.F),
                    1,
                    jnp.where(kind == int(OpKind.B), 1 + C, 1 + 2 * C),
                )
                return jnp.where(kind == int(OpKind.IDLE), 0, base + chunk)

            # -- one tick ----------------------------------------------------- #
            def tick(state, t):
                idx = branch_index(t["kind"], t["chunk"])
                state, send_full = jax.lax.switch(idx, branches, state, t)
                send_val = to_chan(send_full)
                zero_chan = jnp.zeros(chan_shape, prog.act_dtype)

                # local (same-stage) deposit: chunk turns in V placement
                is_local_act = t["send_local"] & ~t["local_is_grad"]
                is_local_grad = t["send_local"] & t["local_is_grad"]
                flat_a = state["act_in"].reshape((-1,) + chan_shape)
                flat_g = state["grad_in"].reshape((-1,) + chan_shape)
                a_idx = t["local_chunk"] * S_act + t["local_slot"]
                g_idx = t["local_chunk"] * S_grad + t["local_slot"]
                flat_a = _masked_set(flat_a, a_idx, send_val, is_local_act)
                flat_g = _masked_set(flat_g, g_idx, send_val, is_local_grad)

                # channel sends: one collective-permute per live channel
                for d in self.channels:
                    payload = jnp.where(
                        t["send_channel"] == d, send_val, zero_chan
                    )
                    shift = _CHANNEL_SHIFT[d]
                    p = plan.p
                    perm = [(i, (i + shift) % p) for i in range(p)]
                    got = jax.lax.ppermute(payload, self.pipe_axis, perm)
                    is_act_chan = d in (CHANNEL_FWD_UP, CHANNEL_FWD_DOWN)
                    valid = t["recv_valid"][d]
                    ridx = t["recv_chunk"][d] * (
                        S_act if is_act_chan else S_grad
                    ) + t["recv_slot"][d]
                    if is_act_chan:
                        flat_a = _masked_set(flat_a, ridx, got, valid)
                    else:
                        flat_g = _masked_set(flat_g, ridx, got, valid)

                state = dict(state)
                state["act_in"] = flat_a.reshape((C, S_act) + chan_shape)
                state["grad_in"] = flat_g.reshape((C, S_grad) + chan_shape)
                return state, None

            # grad_acc over chunks must be a tuple for the _tree ops
            state0["grad_acc"] = tuple(
                jax.tree_util.tree_map(
                    lambda pleaf: jnp.zeros(pleaf.shape, acc_dt(pleaf)), sp
                )
                for sp in stage_params
            )

            if self.mode == "specialized":
                state = self._run_specialized(
                    state0,
                    branches,
                    sidx,
                    share_res,
                    share_wctx,
                    S_act,
                    S_grad,
                    chan_shape,
                    to_chan,
                    zero_act,
                )
            elif self.mode == "unroll":
                xs = self._tick_rows(sidx, share_res, share_wctx)
                state = state0
                for t_i in range(plan.n_ticks):
                    t = jax.tree_util.tree_map(lambda a: a[t_i], xs)
                    state, _ = tick(state, t)
            else:
                xs = self._tick_rows(sidx, share_res, share_wctx)
                state, _ = jax.lax.scan(
                    tick, state0, xs, length=plan.n_ticks
                )

            grads = state["grad_acc"]
            shared_grads = jax.lax.psum(state["shared_acc"], self.pipe_axis)
            loss = jax.lax.psum(state["loss"], self.pipe_axis)
            return grads, shared_grads, loss

        return grad_fn

    # ------------------------------------------------------------------ #
    # generic modes: per-stage (T,)-rows of the tick tables
    # ------------------------------------------------------------------ #
    def _tick_rows(self, sidx, share_res, share_wctx):
        plan = self.plan

        def row(tab):
            return jnp.asarray(tab)[sidx]

        return dict(
            kind=row(plan.op_kind),
            chunk=row(plan.op_chunk),
            mb=row(plan.op_mb),
            in_slot=row(plan.op_in_slot),
            res_slot=row(
                plan.op_res_slot_joint if share_res else plan.op_res_slot
            ),
            wctx_slot=row(
                plan.op_wctx_slot_joint if share_wctx else plan.op_wctx_slot
            ),
            sink_slot=row(plan.op_sink_slot),
            sink_wctx_slot=row(plan.op_sink_wctx_slot),
            is_src=row(plan.op_is_src),
            is_loss=row(plan.op_is_loss),
            is_last_b=row(plan.op_is_last_b),
            send_channel=row(plan.send_channel),
            send_local=row(plan.send_local),
            local_chunk=row(plan.local_chunk),
            local_slot=row(plan.local_slot),
            local_is_grad=row(plan.local_is_grad),
            recv_valid=row(plan.recv_valid),
            recv_chunk=row(plan.recv_chunk),
            recv_slot=row(plan.recv_slot),
        )

    # ------------------------------------------------------------------ #
    # specialized mode: trace each tick against its host-constant column
    # ------------------------------------------------------------------ #
    def _run_specialized(
        self,
        state0,
        branches,
        sidx,
        share_res,
        share_wctx,
        S_act,
        S_grad,
        chan_shape,
        to_chan,
        zero_act,
    ):
        """Unroll the tick stream with per-tick Python constants.

        Per tick: the (kind, chunk) column selects a *direct* branch call
        (or a 2-way ``cond`` / minimal ``switch`` when stages disagree);
        a ``ppermute`` is emitted only for (tick, channel) pairs where the
        plan actually sends, with the exact (sender, receiver) edge list;
        slot indices uniform across the participating stages become static
        update indices.  The steady window (``plan.steady_window()``)
        compiles once inside a ``lax.scan`` superstep with the microbatch
        advanced by ``mb_delta`` per period, bounding trace size at large
        ``p*m``.  Arithmetic, op order, and accumulation order are
        identical to the generic modes, so results are bit-identical.
        """
        plan = self.plan
        C = plan.n_chunks
        p = plan.p

        def pscal(vec, mask=None):
            """(p,) column -> per-stage scalar.  Host columns fold to a
            Python constant when the participating stages agree (static
            slot indices); traced columns (scanned steady-state inputs)
            and disagreeing stages become a tiny gather by stage index."""
            if isinstance(vec, jax.Array):
                return vec[sidx]
            v = np.asarray(vec)
            sel = v if mask is None else v[mask]
            if sel.size and (sel == sel.flat[0]).all():
                return sel.flat[0].item()
            return jnp.asarray(v)[sidx]

        def _pred(mask):
            return True if mask.all() else jnp.asarray(mask)[sidx]

        def make_t(col, mask):
            return dict(
                mb=pscal(col["op_mb"], mask),
                in_slot=pscal(col["op_in_slot"], mask),
                res_slot=pscal(
                    col["op_res_slot_joint"]
                    if share_res
                    else col["op_res_slot"],
                    mask,
                ),
                wctx_slot=pscal(
                    col["op_wctx_slot_joint"]
                    if share_wctx
                    else col["op_wctx_slot"],
                    mask,
                ),
                sink_slot=pscal(col["op_sink_slot"], mask),
                sink_wctx_slot=pscal(col["op_sink_wctx_slot"], mask),
                is_src=pscal(col["op_is_src"], mask),
                is_loss=pscal(col["op_is_loss"], mask),
                is_last_b=pscal(col["op_is_last_b"], mask),
            )

        def branch_vec(col):
            kind, chunk = col["op_kind"], col["op_chunk"]
            base = np.where(
                kind == int(OpKind.F),
                1,
                np.where(kind == int(OpKind.B), 1 + C, 1 + 2 * C),
            )
            return np.where(kind == int(OpKind.IDLE), 0, base + chunk)

        def spec_tick(state, col):
            bidx = branch_vec(col)
            used = sorted(set(bidx.tolist()))

            def wrap(u):
                if u == 0:
                    return lambda st: (st, zero_act)
                tu = make_t(col, bidx == u)
                return lambda st: branches[u](st, tu)

            if used == [0]:
                send = zero_act
            elif len(used) == 1:
                state, send = wrap(used[0])(state)
            elif len(used) == 2:
                pred = jnp.asarray(bidx == used[1])[sidx]
                state, send = jax.lax.cond(
                    pred, wrap(used[1]), wrap(used[0]), state
                )
            else:
                lut = np.searchsorted(used, bidx)
                state, send = jax.lax.switch(
                    jnp.asarray(lut)[sidx], [wrap(u) for u in used], state
                )

            # -- communication: exactly what the plan does at this tick --- #
            live = [
                d for d in self.channels if (col["send_channel"] == d).any()
            ]
            any_local = bool(col["send_local"].any())
            if not live and not any_local:
                return state
            send_val = to_chan(send)
            flat_a = state["act_in"].reshape((-1,) + chan_shape)
            flat_g = state["grad_in"].reshape((-1,) + chan_shape)
            if any_local:
                la = col["send_local"] & ~col["local_is_grad"]
                lg = col["send_local"] & col["local_is_grad"]
                if la.any():
                    idx = col["local_chunk"] * S_act + col["local_slot"]
                    flat_a = _masked_set(
                        flat_a, pscal(idx, la), send_val, _pred(la)
                    )
                if lg.any():
                    idx = col["local_chunk"] * S_grad + col["local_slot"]
                    flat_g = _masked_set(
                        flat_g, pscal(idx, lg), send_val, _pred(lg)
                    )
            for d in live:
                shift = _CHANNEL_SHIFT[d]
                senders = np.nonzero(col["send_channel"] == d)[0]
                edges = [(int(s), int((s + shift) % p)) for s in senders]
                got = jax.lax.ppermute(send_val, self.pipe_axis, edges)
                valid = col["recv_valid"][:, d]
                is_act_chan = d in (CHANNEL_FWD_UP, CHANNEL_FWD_DOWN)
                stride = S_act if is_act_chan else S_grad
                ridx = col["recv_chunk"][:, d] * stride + col["recv_slot"][:, d]
                if is_act_chan:
                    flat_a = _masked_set(
                        flat_a, pscal(ridx, valid), got, _pred(valid)
                    )
                else:
                    flat_g = _masked_set(
                        flat_g, pscal(ridx, valid), got, _pred(valid)
                    )
            state = dict(state)
            state["act_in"] = flat_a.reshape((C, S_act) + chan_shape)
            state["grad_in"] = flat_g.reshape((C, S_grad) + chan_shape)
            return state

        cols = [plan.tick_column(t) for t in range(plan.n_ticks)]
        sw = plan.steady_window() if self.steady_scan else None
        state = state0
        if sw is not None and sw.repeats >= 2:
            for t_i in range(sw.start):
                state = spec_tick(state, cols[t_i])

            # Split each tick-in-period's tables into host constants
            # (identical in every period -- all structural tables are, by
            # the window's definition, and slot tables often too) and
            # per-period scanned inputs (cycling slot ids, microbatch ids).
            const_cols: List[Dict[str, Any]] = []
            var_cols: List[Dict[str, jax.Array]] = []
            for i in range(sw.period):
                ticks = [sw.start + i + j * sw.period for j in range(sw.repeats)]
                cc: Dict[str, Any] = {}
                vv: Dict[str, jax.Array] = {}
                for name in ExecutionPlan._TICK_TABLES:
                    stack = np.stack(
                        [getattr(plan, name)[:, t] for t in ticks]
                    )
                    if (stack == stack[0]).all():
                        cc[name] = stack[0]
                    else:
                        vv[name] = jnp.asarray(stack)
                const_cols.append(cc)
                var_cols.append(vv)

            def superstep(st, xs_i):
                for i in range(sw.period):
                    col = dict(const_cols[i])
                    col.update(xs_i[i])
                    st = spec_tick(st, col)
                return st, None

            state, _ = jax.lax.scan(superstep, state, var_cols)
            tail = range(sw.stop, plan.n_ticks)
        else:
            tail = range(plan.n_ticks)
        for t_i in tail:
            state = spec_tick(state, cols[t_i])
        return state
