"""Content-keyed on-disk plan cache (JSON) + schedule (de)serialization.

Schedule search is pure planning -- the result depends only on the search
inputs -- so it is cached across *processes*, not just in-process: the
``v_flex`` portfolio (keyed ``(p, m, act_limit, times, compact)``) and the
unified planner's decisions (additionally keyed by the HBM budget and the
config content) are written as small JSON files under one cache directory.
A budget sweep re-run in a fresh process, a CI shard, or a second launcher
replays the stored plan instead of re-searching.

Keys are content hashes: every key field is canonicalized to JSON
(dataclasses included, ``TimeModel`` via :func:`times_payload`) and hashed,
so two processes agree on the key iff they agree on the *content* of the
inputs.  Values are self-contained: a serialized :class:`Schedule` (op
lists + placement) plus arbitrary JSON metadata, enough to reconstruct an
identical plan without re-running the search.

Location: ``$REPRO_PLAN_CACHE_DIR`` when set (``0``/``off`` disables
caching entirely), else ``~/.cache/repro-zb/plans``.  Writes are atomic
(tmp + rename); a corrupt or unreadable entry is treated as a miss.
"""

from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import tempfile
from typing import Any, Dict, Optional

from .schedules.ir import Op, OpKind, Placement, Schedule

__all__ = [
    "PlanCache",
    "default_cache",
    "times_payload",
    "schedule_to_payload",
    "schedule_from_payload",
]

_ENV = "REPRO_PLAN_CACHE_DIR"
_VERSION = 1  # bump to invalidate every stored entry on format changes


def times_payload(times) -> Any:
    """Canonical JSON value for a TimeModel (or None)."""
    if times is None:
        return None
    d = dataclasses.asdict(times)
    if d.get("stage_scale") is not None:
        d["stage_scale"] = list(d["stage_scale"])
    return d


def schedule_to_payload(schedule: Schedule) -> Dict[str, Any]:
    return {
        "p": schedule.p,
        "m": schedule.m,
        "name": schedule.name,
        "placement": [list(seq) for seq in schedule.placement.stage_seq],
        "stage_ops": [
            [[int(op.kind), op.mb, op.chunk] for op in ops]
            for ops in schedule.stage_ops
        ],
    }


def schedule_from_payload(payload: Dict[str, Any]) -> Schedule:
    placement = Placement(tuple(tuple(seq) for seq in payload["placement"]))
    stage_ops = [
        [Op(OpKind(k), mb, chunk) for k, mb, chunk in ops]
        for ops in payload["stage_ops"]
    ]
    return Schedule(
        payload["p"],
        payload["m"],
        stage_ops,
        placement=placement,
        name=payload.get("name", "cached"),
    )


def _canonical(value: Any) -> Any:
    """JSON-serializable canonical form of a key field."""
    if dataclasses.is_dataclass(value) and not isinstance(value, type):
        return _canonical(dataclasses.asdict(value))
    if isinstance(value, dict):
        return {str(k): _canonical(v) for k, v in sorted(value.items())}
    if isinstance(value, (list, tuple)):
        return [_canonical(v) for v in value]
    if isinstance(value, float) and value in (float("inf"), float("-inf")):
        return repr(value)
    return value


class PlanCache:
    """Tiny content-addressed JSON store: key(**fields) -> get/put."""

    def __init__(self, cache_dir: Optional[str] = None, enabled: bool = True):
        self.cache_dir = cache_dir
        self.enabled = enabled and cache_dir is not None

    @staticmethod
    def key(kind: str, **fields) -> str:
        blob = json.dumps(
            {"version": _VERSION, "kind": kind, **_canonical(fields)},
            sort_keys=True,
        )
        return f"{kind}-{hashlib.sha256(blob.encode()).hexdigest()[:24]}"

    def _path(self, key: str) -> str:
        return os.path.join(self.cache_dir, f"{key}.json")

    def get(self, key: str) -> Optional[Dict[str, Any]]:
        if not self.enabled:
            return None
        try:
            with open(self._path(key)) as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    def put(self, key: str, payload: Dict[str, Any]) -> None:
        if not self.enabled:
            return
        try:
            os.makedirs(self.cache_dir, exist_ok=True)
            fd, tmp = tempfile.mkstemp(
                dir=self.cache_dir, prefix=f".{key}.", suffix=".tmp"
            )
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f)
            os.replace(tmp, self._path(key))
        except OSError:
            pass  # caching is best-effort; planning proceeds uncached


def default_cache() -> PlanCache:
    """The process-default cache honoring ``$REPRO_PLAN_CACHE_DIR``."""
    env = os.environ.get(_ENV)
    if env is not None:
        if env.strip().lower() in ("", "0", "off", "none", "disabled"):
            return PlanCache(None, enabled=False)
        return PlanCache(env)
    return PlanCache(os.path.join(os.path.expanduser("~"), ".cache", "repro-zb", "plans"))
