from . import adamw, postval
from .adamw import AdamWConfig, AdamWState
from .postval import Decision, GradStats

__all__ = [
    "adamw",
    "postval",
    "AdamWConfig",
    "AdamWState",
    "Decision",
    "GradStats",
]
