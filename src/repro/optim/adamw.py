"""AdamW with arithmetically-reversible in-place rollback (paper Alg. 1).

The optimizer-post-validation strategy (paper Sec. 4) applies an *optimistic*
step using partially-reduced global statistics; if the fully-reduced
statistics later prove the decision wrong (clipping needed / NaN), the step is
rolled back and redone.  Storing a historic copy of params+moments would cost
3x memory and copies; instead the AdamW step function is inverted exactly:

    STEP:      t+=1;  m = b1 m + (1-b1) g;   v = b2 v + (1-b2) g^2
               theta = theta - lr*wd*theta - lr * m_hat / (sqrt(v_hat)+eps)
    ROLLBACK:  theta = (theta + lr * m_hat / (sqrt(v_hat)+eps)) / (1 - lr*wd)
               m = (m - (1-b1) g)/b1;  v = (v - (1-b2) g^2)/b2;  t-=1

Rollback needs only ``g`` (still resident from the backward) and recomputes
the previous state bit-for-bit up to float rounding -- no extra memory.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Union

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = ["AdamWConfig", "AdamWState", "init", "step", "rollback"]


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 1e-3
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: Optional[float] = 1.0  # global-norm clip threshold


class AdamWState(NamedTuple):
    t: jax.Array  # scalar int32 timestep
    m: PyTree  # first moment, fp32
    v: PyTree  # second moment, fp32


def init(params: PyTree) -> AdamWState:
    zeros = lambda: jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params
    )
    return AdamWState(t=jnp.zeros((), jnp.int32), m=zeros(), v=zeros())


def _hat(x, beta, t):
    return x / (1.0 - beta**t)


def step(
    params: PyTree,
    state: AdamWState,
    grads: PyTree,
    cfg: AdamWConfig,
    scale: Union[jax.Array, float] = 1.0,
) -> tuple[PyTree, AdamWState]:
    """One AdamW step on ``scale * grads`` (scale carries the clip factor)."""
    t = state.t + 1
    tf = t.astype(jnp.float32)
    p_leaves, tdef = jax.tree_util.tree_flatten(params)
    m_leaves = jax.tree_util.tree_leaves(state.m)
    v_leaves = jax.tree_util.tree_leaves(state.v)
    g_leaves = jax.tree_util.tree_leaves(grads)

    new_p, new_m, new_v = [], [], []
    for p, m, v, g in zip(p_leaves, m_leaves, v_leaves, g_leaves):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1.0 - cfg.b1) * g
        v = cfg.b2 * v + (1.0 - cfg.b2) * g * g
        m_hat = _hat(m, cfg.b1, tf)
        v_hat = _hat(v, cfg.b2, tf)
        p32 = p.astype(jnp.float32)
        p32 = p32 - cfg.lr * cfg.weight_decay * p32 - cfg.lr * m_hat / (
            jnp.sqrt(v_hat) + cfg.eps
        )
        new_p.append(p32.astype(p.dtype))
        new_m.append(m)
        new_v.append(v)

    unf = lambda leaves: jax.tree_util.tree_unflatten(tdef, leaves)
    return unf(new_p), AdamWState(t=t, m=unf(new_m), v=unf(new_v))


def rollback(
    params: PyTree,
    state: AdamWState,
    grads: PyTree,
    cfg: AdamWConfig,
    scale: Union[jax.Array, float] = 1.0,
) -> tuple[PyTree, AdamWState]:
    """Exact inverse of :func:`step` (paper Algorithm 1, lines 13-20)."""
    tf = state.t.astype(jnp.float32)
    p_leaves, tdef = jax.tree_util.tree_flatten(params)
    m_leaves = jax.tree_util.tree_leaves(state.m)
    v_leaves = jax.tree_util.tree_leaves(state.v)
    g_leaves = jax.tree_util.tree_leaves(grads)

    prev_p, prev_m, prev_v = [], [], []
    for p, m, v, g in zip(p_leaves, m_leaves, v_leaves, g_leaves):
        g = g.astype(jnp.float32) * scale
        m_hat = _hat(m, cfg.b1, tf)
        v_hat = _hat(v, cfg.b2, tf)
        p32 = p.astype(jnp.float32)
        p32 = (p32 + cfg.lr * m_hat / (jnp.sqrt(v_hat) + cfg.eps)) / (
            1.0 - cfg.lr * cfg.weight_decay
        )
        prev_p.append(p32.astype(p.dtype))
        prev_m.append((m - (1.0 - cfg.b1) * g) / cfg.b1)
        prev_v.append((v - (1.0 - cfg.b2) * g * g) / cfg.b2)

    unf = lambda leaves: jax.tree_util.tree_unflatten(tdef, leaves)
    return unf(prev_p), AdamWState(t=state.t - 1, m=unf(prev_m), v=unf(prev_v))
