"""Gradient compression for the DP all-reduce, with error feedback.

At multi-pod scale the once-per-step gradient all-reduce crosses the slowest
links (pods).  ``compress``/``decompress`` implement per-leaf symmetric int8
quantization (absmax scaling) and bf16 truncation; ``ef_correct`` carries the
quantization residual into the next step (error feedback), which keeps SGD /
Adam convergence unbiased in expectation.

Wire savings: bf16 = 2x over fp32 grads, int8 = 4x.  Compression is applied
*before* the dp psum and decompressed after (psum of int8 is done in int32).
"""

from __future__ import annotations

from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = ["compress", "decompress", "ef_correct", "compressed_psum"]


def compress(g: jax.Array, mode: str) -> Tuple[jax.Array, Optional[jax.Array]]:
    if mode == "bf16":
        return g.astype(jnp.bfloat16), None
    if mode == "int8":
        scale = jnp.max(jnp.abs(g)).astype(jnp.float32) / 127.0 + 1e-12
        q = jnp.clip(jnp.round(g.astype(jnp.float32) / scale), -127, 127)
        return q.astype(jnp.int8), scale
    raise ValueError(mode)


def decompress(q: jax.Array, scale: Optional[jax.Array], dtype) -> jax.Array:
    if q.dtype == jnp.int8 or q.dtype == jnp.int32:
        return (q.astype(jnp.float32) * scale).astype(dtype)
    return q.astype(dtype)


def ef_correct(g: jax.Array, restored: jax.Array) -> jax.Array:
    """Error-feedback residual to add to next step's gradient."""
    return (g.astype(jnp.float32) - restored.astype(jnp.float32)).astype(g.dtype)


def compressed_psum(
    grads: PyTree, axis_name: str, mode: str = "bf16", ef: Optional[PyTree] = None
) -> Tuple[PyTree, PyTree]:
    """psum over ``axis_name`` with compressed payloads + error feedback.

    Returns (summed grads in original dtype, new error-feedback state).
    """

    def one(g, e):
        g_in = g if e is None else g + e.astype(g.dtype)
        if mode == "int8":
            # all ranks must quantize in the SAME units: share the absmax
            local_max = jnp.max(jnp.abs(g_in)).astype(jnp.float32)
            scale = jax.lax.pmax(local_max, axis_name) / 127.0 + 1e-12
            q = jnp.clip(
                jnp.round(g_in.astype(jnp.float32) / scale), -127, 127
            ).astype(jnp.int8)
            s = jax.lax.psum(q.astype(jnp.int32), axis_name)
            restored_local = decompress(q, scale, g.dtype)
            out = decompress(s, scale, g.dtype)
        else:
            q, _ = compress(g_in, mode)
            s = jax.lax.psum(q, axis_name)
            restored_local = q.astype(g.dtype)
            out = s.astype(g.dtype)
        new_e = ef_correct(g_in, restored_local)
        return out, new_e

    if ef is None:
        ef = jax.tree_util.tree_map(lambda _: None, grads)
    pairs = jax.tree_util.tree_map(
        one, grads, ef, is_leaf=lambda x: x is None or isinstance(x, jax.Array)
    )
    out = jax.tree_util.tree_map(lambda p: p[0], pairs, is_leaf=lambda x: isinstance(x, tuple))
    new_ef = jax.tree_util.tree_map(lambda p: p[1], pairs, is_leaf=lambda x: isinstance(x, tuple))
    return out, new_ef
