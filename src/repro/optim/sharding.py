"""ZeRO-1: optimizer-state sharding over the data-parallel axis.

Each dp rank owns 1/dp of every parameter (flattened + padded), keeps
optimizer moments only for its shard, and after the step all-gathers the
updated shards.  Gradients arrive via reduce-scatter instead of all-reduce
(same wire bytes, half the per-rank reduction work).  Used inside shard_map
(axis must be bound).
"""

from __future__ import annotations

from typing import Any, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = [
    "shard_leaf",
    "unshard_leaf",
    "scatter_grads",
    "gather_params",
    "zero1_state_bytes",
]


def _pad_len(n: int, dp: int) -> int:
    return (n + dp - 1) // dp * dp


def zero1_state_bytes(
    params: PyTree,
    dp_size: int,
    n_moments: int = 2,
    moment_dtype_bytes: int = 4,
) -> float:
    """Per-rank optimizer-state bytes under ZeRO-1 sharding.

    AdamW keeps ``n_moments`` fp32 mirrors (m, v) of every parameter;
    each dp rank holds the padded 1/dp flat shard of each leaf (the same
    ``_pad_len`` rule ``shard_leaf`` applies), so this is the byte-exact
    planning counterpart of the runtime sharding above.  ``params`` may be
    arrays or ``ShapeDtypeStruct`` pytrees -- only shapes are read.
    """
    import numpy as np

    dp = max(1, int(dp_size))
    elems = 0
    for leaf in jax.tree_util.tree_leaves(params):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        elems += _pad_len(n, dp) // dp
    return float(elems * n_moments * moment_dtype_bytes)


def shard_leaf(x: jax.Array, axis_name: str) -> jax.Array:
    """This rank's flat shard of a (replicated) leaf."""
    dp = jax.lax.psum(1, axis_name)
    r = jax.lax.axis_index(axis_name)
    flat = x.reshape(-1)
    k = _pad_len(flat.shape[0], dp) // dp
    flat = jnp.pad(flat, (0, k * dp - flat.shape[0]))
    return jax.lax.dynamic_slice_in_dim(flat, r * k, k)


def unshard_leaf(shard: jax.Array, shape, dtype, axis_name: str) -> jax.Array:
    """All-gather shards back into the full leaf."""
    full = jax.lax.all_gather(shard, axis_name, axis=0, tiled=True)
    n = 1
    for d in shape:
        n *= d
    return full[:n].reshape(shape).astype(dtype)


def scatter_grads(grads: PyTree, axis_name: str) -> PyTree:
    """reduce-scatter: each rank gets the dp-mean of its flat grad shard."""
    dp = jax.lax.psum(1, axis_name)

    def one(g):
        flat = g.reshape(-1)
        k = _pad_len(flat.shape[0], dp)
        flat = jnp.pad(flat, (0, k - flat.shape[0]))
        return (
            jax.lax.psum_scatter(flat, axis_name, scatter_dimension=0, tiled=True)
            / dp
        )

    return jax.tree_util.tree_map(one, grads)


def gather_params(shards: PyTree, proto: PyTree, axis_name: str) -> PyTree:
    return jax.tree_util.tree_map(
        lambda s, p: unshard_leaf(s, p.shape, p.dtype, axis_name), shards, proto
    )
