"""Optimizer post-validation (paper Sec. 4, Fig. 4, Appendix C).

Classic pipelines block on a global all-reduce before every optimizer step
(NaN/Inf check for mixed precision, global grad-norm for clipping); that
synchronization breaks the zero-bubble parallelogram.  Post-validation
replaces it:

  1. a *partially* reduced state flows stage-to-stage along the pipe axis
     (folded into the schedule's tail; a ppermute chain, never a blocking
     all-reduce);
  2. each stage applies an *optimistic* step controlled by its partial state
     (skip if a NaN is already visible or the partial norm already exceeds
     the clip threshold);
  3. when the fully reduced state arrives, each stage validates its decision
     and, on mis-speculation, performs the in-place rollback (Alg. 1) and
     redoes the step with the correct global clip scale.

Two modes:
  * ``within_step``: relay + validation inside the same train step (the relay
    overlaps the W tail; nothing is carried across steps);
  * ``deferred``: the paper's placement -- validation happens at the head of
    the *next* step; gradients and the speculative decision ride the train
    carry.  Numerically both are exactly the synchronous semantics.
"""

from __future__ import annotations

import dataclasses
from typing import Any, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp

from . import adamw

PyTree = Any

__all__ = [
    "GradStats",
    "Decision",
    "local_stats",
    "combine_stats",
    "decide_partial",
    "decide_global",
    "optimistic_step",
    "validate_and_fix",
    "pipe_prefix_stats",
    "sync_step",
]


class GradStats(NamedTuple):
    sumsq: jax.Array  # sum of squared gradient entries (fp32 scalar)
    nonfinite: jax.Array  # bool scalar: any NaN/Inf seen


class Decision(NamedTuple):
    applied: jax.Array  # bool: did we apply an (unscaled) optimistic step
    scale: jax.Array  # f32: the scale used (1.0 for optimistic steps)


def local_stats(grads: PyTree) -> GradStats:
    leaves = jax.tree_util.tree_leaves(grads)
    sumsq = jnp.zeros((), jnp.float32)
    bad = jnp.zeros((), bool)
    for g in leaves:
        g32 = g.astype(jnp.float32)
        sumsq = sumsq + jnp.sum(g32 * g32)
        bad = bad | ~jnp.all(jnp.isfinite(g32))
    return GradStats(sumsq, bad)


def combine_stats(a: GradStats, b: GradStats) -> GradStats:
    return GradStats(a.sumsq + b.sumsq, a.nonfinite | b.nonfinite)


def pipe_prefix_stats(stats: GradStats, axis_name: str) -> Tuple[GradStats, GradStats]:
    """(inclusive prefix, full) reduction along the pipe axis.

    Implemented as a log-depth scan of ppermutes (never a blocking fused
    all-reduce at the optimizer boundary; each hop is a neighbour exchange
    that XLA overlaps with the W tail).  Returns the partially-reduced state
    each stage would see in the paper's relay plus the fully-reduced state.
    """
    p = jax.lax.psum(1, axis_name)
    idx = jax.lax.axis_index(axis_name)
    pre_sq, pre_bad = stats.sumsq, stats.nonfinite.astype(jnp.float32)
    shift = 1
    while shift < p:
        perm = [(i, i + shift) for i in range(p - shift)]
        got_sq = jax.lax.ppermute(pre_sq, axis_name, perm)
        got_bad = jax.lax.ppermute(pre_bad, axis_name, perm)
        take = idx >= shift
        pre_sq = pre_sq + jnp.where(take, got_sq, 0.0)
        pre_bad = jnp.maximum(pre_bad, jnp.where(take, got_bad, 0.0))
        shift *= 2
    partial = GradStats(pre_sq, pre_bad > 0.5)
    # full state: the last stage's prefix, broadcast back (paper: propagated
    # during the next warm-up); a reversed ppermute chain again.
    full_sq, full_bad = pre_sq, pre_bad
    shift = 1
    while shift < p:
        perm = [(i, i - shift) for i in range(shift, p)]
        got_sq = jax.lax.ppermute(full_sq, axis_name, perm)
        got_bad = jax.lax.ppermute(full_bad, axis_name, perm)
        take = idx < p - shift
        full_sq = jnp.where(take, got_sq, full_sq)
        full_bad = jnp.where(take, got_bad, full_bad)
        shift *= 2
    full = GradStats(full_sq, full_bad > 0.5)
    return partial, full


def decide_partial(partial: GradStats, cfg: adamw.AdamWConfig) -> Decision:
    """Optimistic decision from a partially-reduced state (paper Sec. 4)."""
    clip = cfg.grad_clip
    ok = ~partial.nonfinite
    if clip is not None:
        ok = ok & (jnp.sqrt(partial.sumsq) <= clip)
    return Decision(applied=ok, scale=jnp.float32(1.0))


def decide_global(full: GradStats, cfg: adamw.AdamWConfig) -> Decision:
    """The synchronous-semantics decision from the fully-reduced state."""
    norm = jnp.sqrt(full.sumsq)
    if cfg.grad_clip is None:
        scale = jnp.float32(1.0)
    else:
        scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(norm, 1e-20))
    return Decision(applied=~full.nonfinite, scale=scale.astype(jnp.float32))


def optimistic_step(
    params: PyTree,
    state: adamw.AdamWState,
    grads: PyTree,
    partial: GradStats,
    cfg: adamw.AdamWConfig,
) -> Tuple[PyTree, adamw.AdamWState, Decision]:
    dec = decide_partial(partial, cfg)

    def do(_):
        return adamw.step(params, state, grads, cfg, scale=1.0)

    def skip(_):
        return params, state

    new_params, new_state = jax.lax.cond(dec.applied, do, skip, None)
    return new_params, new_state, dec


def validate_and_fix(
    params: PyTree,
    state: adamw.AdamWState,
    grads: PyTree,
    speculative: Decision,
    full: GradStats,
    cfg: adamw.AdamWConfig,
) -> Tuple[PyTree, adamw.AdamWState, jax.Array]:
    """Rollback + redo when the optimistic decision was wrong.

    Returns (params, state, amended?) where amended is a bool scalar counting
    mis-speculations (rare in robust training -- the paper's premise).
    """
    want = decide_global(full, cfg)
    # legit iff: we applied with scale 1 and the true decision is apply@1.0,
    # or we skipped and the true decision is skip.
    applied_ok = speculative.applied & want.applied & (want.scale >= 1.0 - 1e-12)
    skipped_ok = (~speculative.applied) & (~want.applied)
    legit = applied_ok | skipped_ok

    def fix(_):
        # undo whatever we did, then redo the true decision
        def undo(_):
            return adamw.rollback(params, state, grads, cfg, scale=1.0)

        p0, s0 = jax.lax.cond(speculative.applied, undo, lambda _: (params, state), None)

        def redo(_):
            return adamw.step(p0, s0, grads, cfg, scale=want.scale)

        return jax.lax.cond(want.applied, redo, lambda _: (p0, s0), None)

    new_params, new_state = jax.lax.cond(
        legit, lambda _: (params, state), fix, None
    )
    return new_params, new_state, ~legit


def sync_step(
    params: PyTree,
    state: adamw.AdamWState,
    grads: PyTree,
    cfg: adamw.AdamWConfig,
    stats: Optional[GradStats] = None,
) -> Tuple[PyTree, adamw.AdamWState]:
    """Reference synchronous semantics: blocking global decision, then step."""
    stats = stats if stats is not None else local_stats(grads)
    want = decide_global(stats, cfg)

    def do(_):
        return adamw.step(params, state, grads, cfg, scale=want.scale)

    return jax.lax.cond(want.applied, do, lambda _: (params, state), None)
