"""Model assembly: ArchConfig -> PipelineProgram for the ZB executor.

A model is a stack of *blocks* (block = one architectural layer, possibly
several sub-kinds, e.g. ("attn", "mlp")), repeated over a pattern.  Blocks
are assigned to (stage, chunk) groups of uniform size and uniform pattern
phase, so every stage traces the *same* chunk function (an SPMD requirement;
see executor.py).  When ``n_layers`` doesn't divide evenly, groups are padded
with mask-disabled blocks: the mask rides in the (stage-varying) parameters
and multiplies the block output, so padded blocks are exact no-ops with zero
gradients; the trainer freezes mask leaves.

Embedding (vocab-parallel) + modality-frontend projections form the shared
``src``; final norm + vocab-parallel head + CE form the shared ``sink``.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

from ..core.executor import PipelineProgram
from ..core.passes import FBWModule, auto_fbw
from .modules import (
    LAYER_KINDS,
    ShardCtx,
    apply_block,
    apply_layer,
    init_layer,
    pad_to_multiple,
    rmsnorm,
    vocab_parallel_ce,
)

PyTree = Any

__all__ = [
    "ArchConfig",
    "RunSpec",
    "ChunkFBW",
    "build_program",
    "init_params",
    "layer_cfg",
]


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    block_pattern: Tuple[Tuple[str, ...], ...] = (("attn", "mlp"),)
    head_dim: Optional[int] = None
    extras: Tuple[Tuple[str, Any], ...] = ()  # hashable dict
    dtype: str = "float32"
    sub_quadratic: bool = False  # eligible for long_500k decode
    has_decoder: bool = True  # False only for pure encoders
    source: str = ""  # provenance note

    def extras_dict(self) -> Dict[str, Any]:
        return dict(self.extras)

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    def jdtype(self):
        return {"float32": jnp.float32, "bfloat16": jnp.bfloat16, "float64": jnp.float64}[
            self.dtype
        ]


@dataclasses.dataclass(frozen=True)
class RunSpec:
    p: int  # pipeline stages
    n_chunks: int  # chunks per stage (1, or 2 for ZB-V / interleaved)
    microbatch: int  # b per microbatch
    seq_len: int
    m: int  # number of microbatches per pipe
    tp_axis: Optional[str] = None
    tp_size: int = 1


def layer_cfg(cfg: ArchConfig, tp_size: int = 1) -> Dict[str, Any]:
    d = dict(
        d_model=cfg.d_model,
        n_heads=cfg.n_heads,
        n_kv_heads=cfg.n_kv_heads,
        d_ff=cfg.d_ff,
        n_layers=cfg.n_layers,
        head_dim=cfg.head_dim,
        tp_size=tp_size,
    )
    d.update(cfg.extras_dict())
    return d


# --------------------------------------------------------------------- #
# block -> group assignment
# --------------------------------------------------------------------- #
def group_layout(cfg: ArchConfig, p: int, n_chunks: int) -> Tuple[Tuple[Tuple[str, ...], ...], int]:
    """Blocks per (stage, chunk) group; returns (group pattern, group size).

    Group size g is the smallest multiple of the pattern period with
    g * p * n_chunks >= n_layers, so every group is pattern-aligned.
    """
    period = cfg.period
    slots = p * n_chunks
    g = max(1, math.ceil(cfg.n_layers / slots))
    g = period * math.ceil(g / period)
    blocks = tuple(cfg.block_pattern[i % period] for i in range(g))
    return blocks, g


def group_masks(cfg: ArchConfig, p: int, n_chunks: int, placement) -> "np.ndarray":
    """(p, n_chunks, g) float mask: 1 for real blocks, 0 for padding."""
    import numpy as np

    _, g = group_layout(cfg, p, n_chunks)
    masks = np.zeros((p, n_chunks, g), np.float32)
    for c in range(n_chunks):
        for k in range(p):
            s = placement.stage_of(c, k)
            pos = c * p + k  # global group order along the model depth
            start = pos * g
            for bi in range(g):
                if start + bi < cfg.n_layers:
                    masks[s, c, bi] = 1.0
    return masks


# --------------------------------------------------------------------- #
# chunk modules: one split-VJP module per architectural block
# --------------------------------------------------------------------- #
def make_chunk_fn(cfg: ArchConfig, p: int, n_chunks: int, ctx: ShardCtx):
    """Whole-chunk forward (reference path; the executor uses ChunkFBW)."""
    blocks, g = group_layout(cfg, p, n_chunks)
    lcfg = layer_cfg(cfg, ctx.tp_size)

    def chunk_fn(params, x, side):
        pos = side["positions"]
        for bi, kinds in enumerate(blocks):
            x = apply_block(
                kinds, params["mask"][bi], params["blocks"][bi], x, pos, lcfg, ctx
            )
        return x

    return chunk_fn, blocks, g


class ChunkFBW(FBWModule):
    """A pipeline chunk as a sequence of per-block split-VJP modules.

    The executor-facing param structure is unchanged (``{"mask": (g,),
    "blocks": (...)}`` -- checkpoints, sharding rules and the optimizer's
    mask freeze are untouched); each block module sees the slice
    ``(mask[bi], blocks[bi])``.  B consumes the block residuals
    right-to-left and emits one compact M_W context per block (the paper's
    per-block kept cotangents + wgrad inputs); W reassembles the chunk
    gradient from those contexts alone.
    """

    def __init__(
        self,
        cfg: ArchConfig,
        p: int,
        n_chunks: int,
        ctx: ShardCtx,
        name: str,
        compact: Optional[bool] = None,
    ):
        blocks, g = group_layout(cfg, p, n_chunks)
        lcfg = layer_cfg(cfg, ctx.tp_size)
        self.name = name
        self.block_kinds = blocks

        def block_fn(kinds):
            def f(params, x, side):
                mask, kp = params
                return apply_block(kinds, mask, kp, x, side["positions"], lcfg, ctx)

            return f

        self.mods = [
            auto_fbw(block_fn(kinds), name=f"{name}.b{bi}", compact=compact)
            for bi, kinds in enumerate(blocks)
        ]

    @staticmethod
    def _bp(params, bi):
        return (params["mask"][bi], params["blocks"][bi])

    def fwd(self, params, x, side):
        res_all = []
        for bi, mod in enumerate(self.mods):
            x, res = mod.fwd(self._bp(params, bi), x, side)
            res_all.append(res)
        return x, tuple(res_all)

    def bwd_x(self, params, res, dy, side):
        wctx_all = [None] * len(self.mods)
        for bi in reversed(range(len(self.mods))):
            dy, w = self.mods[bi].bwd_x(self._bp(params, bi), res[bi], dy, side)
            wctx_all[bi] = w
        return dy, tuple(wctx_all)

    def bwd_w(self, params, wctx, side, acc=None):
        outs = []
        for bi, mod in enumerate(self.mods):
            a = None if acc is None else (acc["mask"][bi], acc["blocks"][bi])
            outs.append(mod.bwd_w(self._bp(params, bi), wctx[bi], side, acc=a))
        return {
            "mask": jnp.stack([o[0] for o in outs]),
            "blocks": tuple(o[1] for o in outs),
        }

    def ensure_traced(self, params, x, side) -> None:
        jax.eval_shape(lambda p, xx, sd: self.fwd(p, xx, sd), params, x, side)


def init_chunk_params(cfg: ArchConfig, key, stage: int, chunk: int, p: int, n_chunks: int, ctx: ShardCtx, masks):
    blocks, g = group_layout(cfg, p, n_chunks)
    lcfg = layer_cfg(cfg, ctx.tp_size)
    dt = cfg.jdtype()
    block_params = []
    for bi, kinds in enumerate(blocks):
        kp = []
        for ki, kind in enumerate(kinds):
            sub = jax.random.fold_in(key, (stage * 97 + chunk * 31 + bi) * 13 + ki)
            kp.append(init_layer(kind, sub, lcfg, ctx, dt))
        block_params.append(tuple(kp))
    return {
        "mask": jnp.asarray(masks[stage, chunk], jnp.float32),
        "blocks": tuple(block_params),
    }


# --------------------------------------------------------------------- #
# src (embedding + frontend) and sink (norm + head + CE)
# --------------------------------------------------------------------- #
def init_shared(cfg: ArchConfig, key, ctx: ShardCtx):
    dt = cfg.jdtype()
    v_pad = pad_to_multiple(cfg.vocab, max(1, ctx.tp_size))
    ks = jax.random.split(key, 4)
    shared = {
        "embed": (jax.random.normal(ks[0], (v_pad, cfg.d_model)) * 0.02).astype(dt),
        "head": (jax.random.normal(ks[1], (cfg.d_model, v_pad)) * 0.02).astype(dt),
        "final_ln": jnp.zeros((cfg.d_model,), dt),
    }
    if cfg.family in ("encdec", "vlm"):
        d_front = cfg.extras_dict().get("frontend_dim", cfg.d_model)
        shared["front_proj"] = (
            jax.random.normal(ks[2], (d_front, cfg.d_model)) * 0.02
        ).astype(dt)
    return shared


def _embed_lookup(shared, tokens, cfg: ArchConfig, ctx: ShardCtx):
    v_l = shared["embed"].shape[0]
    off = ctx.index() * v_l
    loc = tokens - off
    ok = (loc >= 0) & (loc < v_l)
    safe = jnp.clip(loc, 0, v_l - 1)
    x = shared["embed"][safe] * ok[..., None].astype(shared["embed"].dtype)
    return ctx.psum(x) if ctx.tp_axis else x


def _embed_grad(shared, tokens, dx, ctx: ShardCtx):
    v_l = shared["embed"].shape[0]
    off = ctx.index() * v_l
    loc = tokens - off
    ok = (loc >= 0) & (loc < v_l)
    safe = jnp.clip(loc, 0, v_l - 1)
    flat_tok = safe.reshape(-1)
    flat_dx = (dx * ok[..., None].astype(dx.dtype)).reshape(-1, dx.shape[-1])
    g = jnp.zeros_like(shared["embed"], dtype=jnp.promote_types(dx.dtype, jnp.float32))
    return g.at[flat_tok].add(flat_dx.astype(g.dtype))


def make_src(cfg: ArchConfig, ctx: ShardCtx):
    fam = cfg.family
    dt = cfg.jdtype()

    def src_fwd(shared, side_mb):
        tok = side_mb["tokens"]
        x = _embed_lookup(shared, tok, cfg, ctx)
        if fam == "encdec":
            front = side_mb["frames"].astype(dt) @ shared["front_proj"]
            x = jnp.concatenate([front, x], axis=1)
        elif fam == "vlm":
            front = side_mb["patches"].astype(dt) @ shared["front_proj"]
            x = jnp.concatenate([front, x], axis=1)
        return x

    def src_bwd_w(shared, side_mb, dx):
        g = {k: jnp.zeros_like(v, dtype=jnp.float32) for k, v in shared.items()}
        tok = side_mb["tokens"]
        if fam == "encdec":
            nf = side_mb["frames"].shape[1]
            dfront, dtok = dx[:, :nf], dx[:, nf:]
            fr = side_mb["frames"].astype(jnp.float32)
            g["front_proj"] = jnp.einsum("bsf,bsh->fh", fr, dfront.astype(jnp.float32))
        elif fam == "vlm":
            nf = side_mb["patches"].shape[1]
            dfront, dtok = dx[:, :nf], dx[:, nf:]
            fr = side_mb["patches"].astype(jnp.float32)
            g["front_proj"] = jnp.einsum("bsf,bsh->fh", fr, dfront.astype(jnp.float32))
        else:
            dtok = dx
        g["embed"] = _embed_grad(shared, tok, dtok, ctx)
        return g

    return src_fwd, src_bwd_w


def make_sink_fn(cfg: ArchConfig, ctx: ShardCtx, m: int):
    fam = cfg.family

    def sink_fn(shared, y, side_mb):
        if fam == "encdec":
            y = y[:, side_mb["frames"].shape[1] :]
        elif fam == "vlm":
            y = y[:, side_mb["patches"].shape[1] :]
        yn = rmsnorm(shared["final_ln"], y)
        logits = yn @ shared["head"]
        loss = vocab_parallel_ce(logits, side_mb["labels"], ctx, cfg.vocab)
        return loss / m

    return sink_fn


# --------------------------------------------------------------------- #
# program factory
# --------------------------------------------------------------------- #
def build_program(
    cfg: ArchConfig,
    spec: RunSpec,
    placement,
    compact: Optional[bool] = None,
) -> PipelineProgram:
    """``compact`` selects the byte-minimal W-context split (core/passes);
    the default follows ``auto_fbw``'s global default.  ``compact=False``
    is the whole-scan-in-B / frontier-cut baseline the measured-memory
    tests compare against."""
    ctx = ShardCtx(tp_axis=spec.tp_axis, tp_size=spec.tp_size)
    src_fwd, src_bwd_w = make_src(cfg, ctx)
    sink_fn = make_sink_fn(cfg, ctx, spec.m)

    s_total = spec.seq_len
    if cfg.family == "encdec":
        s_total = cfg.extras_dict()["s_enc"] + spec.seq_len
    elif cfg.family == "vlm":
        s_total = cfg.extras_dict()["n_patches"] + spec.seq_len

    chunks = [
        ChunkFBW(
            cfg, spec.p, spec.n_chunks, ctx,
            name=f"{cfg.name}.chunk{c}", compact=compact,
        )
        for c in range(spec.n_chunks)
    ]
    return PipelineProgram(
        chunks=chunks,
        src_fwd=src_fwd,
        src_bwd_w=src_bwd_w,
        sink=auto_fbw(sink_fn, name=f"{cfg.name}.sink", compact=compact),
        act_shape=(spec.microbatch, s_total, cfg.d_model),
        act_dtype=cfg.jdtype(),
    )


def init_params(cfg: ArchConfig, spec: RunSpec, placement, key=None):
    """Returns (stacked_stage_params per chunk, shared params, frozen mask)."""
    import numpy as np

    key = key if key is not None else jax.random.PRNGKey(0)
    ctx = ShardCtx(tp_axis=spec.tp_axis, tp_size=spec.tp_size)
    masks = group_masks(cfg, spec.p, spec.n_chunks, placement)
    stacked = []
    for c in range(spec.n_chunks):
        per_stage = [
            init_chunk_params(cfg, key, s, c, spec.p, spec.n_chunks, ctx, masks)
            for s in range(spec.p)
        ]
        stacked.append(
            jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *per_stage)
        )
    shared = init_shared(cfg, jax.random.fold_in(key, 999), ctx)
    return tuple(stacked), shared


def side_inputs(cfg: ArchConfig, spec: RunSpec, key=None):
    """Synthetic per-microbatch side inputs: tokens, labels, positions."""
    key = key if key is not None else jax.random.PRNGKey(1)
    m, b, s = spec.m, spec.microbatch, spec.seq_len
    ks = jax.random.split(key, 4)
    side = {
        "tokens": jax.random.randint(ks[0], (m, b, s), 0, cfg.vocab),
        "labels": jax.random.randint(ks[1], (m, b, s), 0, cfg.vocab),
    }
    s_total = s
    ex = cfg.extras_dict()
    if cfg.family == "encdec":
        side["frames"] = jax.random.normal(
            ks[2], (m, b, ex["s_enc"], ex.get("frontend_dim", cfg.d_model))
        ).astype(cfg.jdtype())
        s_total = ex["s_enc"] + s
    elif cfg.family == "vlm":
        side["patches"] = jax.random.normal(
            ks[2], (m, b, ex["n_patches"], ex.get("frontend_dim", cfg.d_model))
        ).astype(cfg.jdtype())
        s_total = ex["n_patches"] + s
    side["positions"] = jnp.broadcast_to(jnp.arange(s_total), (m, s_total))
    return side
