"""Serving path: prefill (cache build) and decode (one token) per layer kind.

Decode uses the memory-optimal formulations: GQA attends over k/v caches,
MLA uses the *absorbed* latent form (scores and outputs computed directly
against the cached latent ``c`` -- the whole point of MLA at decode),
recurrent kinds (sLSTM/mLSTM/RG-LRU) carry O(1) state, local attention keeps
a full window cache (ring indexing is a dry-run-neutral refinement).
"""

from __future__ import annotations

import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from .lm import ArchConfig, RunSpec, group_layout, layer_cfg
from .modules import ShardCtx, rmsnorm, rope, _softcap

PyTree = Any

__all__ = [
    "init_cache",
    "decode_block",
    "prefill_block",
    "make_serve_chunk",
    "prefill_block_cp",
]


# --------------------------------------------------------------------- #
# per-kind cache init (batch b, max context S)
# --------------------------------------------------------------------- #
def cache_spec(kind: str, cfg: Dict, ctx: ShardCtx, b: int, S: int, dtype):
    """GLOBAL cache shapes (full kv heads); TP sharding of the heads axis is
    applied by the PartitionSpecs from :func:`cache_pspec`."""
    h = cfg["d_model"]
    hk = cfg["n_kv_heads"]
    dh = cfg.get("head_dim") or h // cfg["n_heads"]
    if kind in ("attn", "attn_local"):
        w = cfg.get("window") if kind == "attn_local" else None
        Sc = min(S, w) if w else S
        return {
            "k": jnp.zeros((b, Sc, hk, dh), dtype),
            "v": jnp.zeros((b, Sc, hk, dh), dtype),
        }
    if kind == "mla":
        d_kv = cfg.get("kv_lora_rank") or 512
        d_rope = cfg.get("qk_rope_head_dim") or 64
        return {
            "c": jnp.zeros((b, S, d_kv), dtype),
            "kr": jnp.zeros((b, S, d_rope), dtype),
        }
    if kind == "slstm":
        return {
            "c": jnp.zeros((b, h), jnp.float32),
            "n": jnp.zeros((b, h), jnp.float32),
            "m": jnp.full((b, h), -1e30, jnp.float32),
        }
    if kind == "mlstm":
        nh = cfg["n_heads"]
        dh_m = h // nh
        return {"C": jnp.zeros((b, nh, dh_m, dh_m), jnp.float32)}
    if kind == "rglru":
        d_r = cfg.get("lru_width") or h
        return {"h": jnp.zeros((b, d_r), jnp.float32)}
    if kind == "encdec":
        s_enc = cfg["s_enc"]
        return {
            "k": jnp.zeros((b, S, hk, dh), dtype),
            "v": jnp.zeros((b, S, hk, dh), dtype),
            "enc": jnp.zeros((b, s_enc, h), dtype),
        }
    if kind in ("mlp", "moe"):
        return {}
    raise ValueError(kind)


def cache_pspec(kind: str, cfg: Dict, tp: "str | None"):
    """PartitionSpecs matching :func:`cache_spec` leaves (body dims only)."""
    from jax.sharding import PartitionSpec as P

    from .modules import _kv_sharded

    kv = P(None, None, tp, None) if (tp and _kv_sharded(cfg)) else P()
    if kind in ("attn", "attn_local"):
        return {"k": kv, "v": kv}
    if kind == "encdec":
        return {"k": kv, "v": kv, "enc": P()}
    if kind == "mla":
        return {"c": P(), "kr": P()}
    if kind == "slstm":
        return {"c": P(), "n": P(), "m": P()}
    if kind == "mlstm":
        return {"C": P()}
    if kind == "rglru":
        return {"h": P()}
    if kind in ("mlp", "moe"):
        return {}
    raise ValueError(kind)


# --------------------------------------------------------------------- #
# decode: one token through one block
# --------------------------------------------------------------------- #
def _cached_attend(q, kc, vc, pos, window=None, softcap=None):
    """q: (b, 1, hq, d); kc/vc: (b, S, hk, d); pos: scalar current index."""
    hq, hk = q.shape[2], kc.shape[2]
    rep = hq // hk
    k = jnp.repeat(kc, rep, axis=2)
    v = jnp.repeat(vc, rep, axis=2)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32)
    logits = logits / math.sqrt(q.shape[-1])
    logits = _softcap(logits, softcap)
    kpos = jnp.arange(kc.shape[1])
    mask = kpos <= pos
    if window:
        mask = mask & (kpos > pos - window)
    logits = jnp.where(mask[None, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, v)


def decode_block(kind, p, x, cache, pos, cfg, ctx: ShardCtx):
    """x: (b, 1, h) -> (y, new_cache)."""
    from .modules import _kv_sharded, _q_sharded, _tp

    b = x.shape[0]
    h = cfg["d_model"]
    tp = _tp(cfg)
    dh = cfg.get("head_dim") or h // cfg["n_heads"]
    hq = cfg["n_heads"] // tp if _q_sharded(cfg) else cfg["n_heads"]
    hk = cfg["n_kv_heads"] // tp if _kv_sharded(cfg) else cfg["n_kv_heads"]
    posv = jnp.full((1,), pos)

    if kind in ("attn", "attn_local"):
        window = cfg.get("window") if kind == "attn_local" else None
        wq = p.get("wq", p.get("wq_rep"))
        wk = p.get("wk", p.get("wk_rep"))
        wv = p.get("wv", p.get("wv_rep"))
        wo = p.get("wo", p.get("wo_rep"))
        xin = rmsnorm(p["ln"], x)
        q = rope((xin @ wq).reshape(b, 1, hq, dh), posv)
        k = rope((xin @ wk).reshape(b, 1, hk, dh), posv)
        v = (xin @ wv).reshape(b, 1, hk, dh)
        Sc = cache["k"].shape[1]
        slot = jnp.mod(pos, Sc) if window else pos
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, slot, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, slot, 0, 0))
        from .modules import _match_kv_heads

        kcm, vcm = _match_kv_heads(hq, kc, vc, cfg, ctx)
        if window:
            o = _ring_attend(q, kcm, vcm, pos, Sc, cfg.get("attn_softcap"))
        else:
            o = _cached_attend(q, kcm, vcm, pos, None, cfg.get("attn_softcap"))
        o = o.reshape(b, 1, hq * dh) @ wo
        y = x + (ctx.psum(o) if _q_sharded(cfg) and tp > 1 else o)
        return y, {"k": kc, "v": vc}

    if kind == "mla":
        d_kv = cfg.get("kv_lora_rank") or 512
        d_rope = cfg.get("qk_rope_head_dim") or 64
        xin = rmsnorm(p["ln"], x)
        q_all = ((xin @ p["wdq"]) @ p["wuq"]).reshape(b, 1, hq, dh + d_rope)
        q_nope, q_rope = q_all[..., :dh], rope(q_all[..., dh:], posv)
        ckv = xin @ p["wdkv"]
        c_new, kr_new = ckv[..., :d_kv], rope(ckv[..., None, d_kv:], posv)[:, :, 0]
        cc = jax.lax.dynamic_update_slice(cache["c"], c_new, (0, pos, 0))
        krc = jax.lax.dynamic_update_slice(cache["kr"], kr_new, (0, pos, 0))
        # absorbed scores: q_nope @ W_uk^T gives a latent-space query
        wuk = p["wuk"].reshape(d_kv, hq, dh)
        q_lat = jnp.einsum("bqhd,khd->bqhk", q_nope, wuk)  # (b,1,hq,d_kv)
        s_lat = jnp.einsum("bqhk,bsk->bhqs", q_lat, cc)
        s_rope = jnp.einsum("bqhd,bsd->bhqs", q_rope, krc)
        logits = (s_lat + s_rope).astype(jnp.float32) / math.sqrt(dh + d_rope)
        kpos = jnp.arange(cc.shape[1])
        logits = jnp.where(kpos[None, None, None, :] <= pos, logits, -1e30)
        probs = jax.nn.softmax(logits, axis=-1).astype(x.dtype)
        ctx_lat = jnp.einsum("bhqs,bsk->bqhk", probs, cc)  # (b,1,hq,d_kv)
        wuv = p["wuv"].reshape(d_kv, hq, dh)
        o = jnp.einsum("bqhk,khd->bqhd", ctx_lat, wuv)
        o = o.reshape(b, 1, hq * dh) @ p["wo"]
        y = x + (ctx.psum(o) if tp > 1 else o)
        return y, {"c": cc, "kr": krc}

    if kind == "mlp":
        from .modules import apply_mlp

        return apply_mlp(p, x, cfg, ctx), cache

    if kind == "moe":
        from .modules import apply_moe

        return apply_moe(p, x, cfg, ctx), cache

    if kind == "slstm":
        xin = rmsnorm(p["ln"], x)[:, 0]
        i_t = (xin @ p["si"]).astype(jnp.float32)
        f_t = (xin @ p["sf"]).astype(jnp.float32)
        z_t = jnp.tanh(xin @ p["sz"]).astype(jnp.float32)
        o_t = jax.nn.sigmoid(xin @ p["sog"]).astype(jnp.float32)
        m_new = jnp.maximum(f_t + cache["m"], i_t)
        i_e = jnp.exp(i_t - m_new)
        f_e = jnp.exp(f_t + cache["m"] - m_new)
        c = f_e * cache["c"] + i_e * z_t
        n = f_e * cache["n"] + i_e
        hs = (c / jnp.maximum(n, 1.0)).astype(x.dtype)
        y = x + ((o_t.astype(x.dtype) * hs) @ p["so"])[:, None]
        return y, {"c": c, "n": n, "m": m_new}

    if kind == "mlstm":
        nh = cfg["n_heads"]
        dh_m = h // nh
        xin = rmsnorm(p["ln"], x)[:, 0]
        q = (xin @ p["mq"]).reshape(b, nh, dh_m)
        k = (xin @ p["mk"]).reshape(b, nh, dh_m) / math.sqrt(dh_m)
        v = (xin @ p["mv"]).reshape(b, nh, dh_m)
        f_g = jax.nn.sigmoid((xin @ p["mfg"]).astype(jnp.float32))  # (b, nh)
        i_g = jax.nn.sigmoid((xin @ p["mig"]).astype(jnp.float32))
        C = cache["C"] * f_g[..., None, None] + jnp.einsum(
            "bhd,bhe->bhde", (k.astype(jnp.float32) * i_g[..., None]), v.astype(jnp.float32)
        )
        out = jnp.einsum("bhd,bhde->bhe", q.astype(jnp.float32), C)
        y = x + (out.reshape(b, h).astype(x.dtype) @ p["mo"])[:, None]
        return y, {"C": C}

    if kind == "rglru":
        xin = rmsnorm(p["ln"], x)[:, 0]
        u = xin @ p["rx"]
        gate_y = jax.nn.gelu(xin @ p["ry"])
        r = jax.nn.sigmoid((u @ p["ra"]).astype(jnp.float32))
        i = jax.nn.sigmoid((u @ p["ri"]).astype(jnp.float32))
        log_a = -8.0 * jax.nn.softplus(p["lam"]) * r
        a = jnp.exp(log_a)
        hs = a * cache["h"] + jnp.sqrt(
            jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)
        ) * i * u.astype(jnp.float32)
        y = x + ((hs.astype(x.dtype) * gate_y) @ p["ro"])[:, None]
        return y, {"h": hs}

    if kind == "encdec":
        # decoder-only step: causal self-attn over cache + cross-attn on enc
        from .modules import _attn_proj, _match_kv_heads, _q_sharded, apply_mlp, attention

        qs = _q_sharded(cfg)
        wq, wk, wv, wo = _attn_proj(p["dec_attn"], cfg)
        xin = rmsnorm(p["dec_attn"]["ln"], x)
        q = rope((xin @ wq).reshape(b, 1, hq, dh), posv)
        k = rope((xin @ wk).reshape(b, 1, hk, dh), posv)
        v = (xin @ wv).reshape(b, 1, hk, dh)
        kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, pos, 0, 0))
        vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, pos, 0, 0))
        kcm, vcm = _match_kv_heads(hq, kc, vc, cfg, ctx)
        o = _cached_attend(q, kcm, vcm, pos)
        o = o.reshape(b, 1, hq * dh) @ wo
        xd = x + (ctx.psum(o) if qs and tp > 1 else o)
        wq2, wk2, wv2, wo2 = _attn_proj(p["xattn"], cfg)
        hin = rmsnorm(p["xattn"]["ln"], xd)
        enc = cache["enc"]
        s_enc = enc.shape[1]
        q2 = (hin @ wq2).reshape(b, 1, hq, dh)
        k2 = (enc @ wk2).reshape(b, s_enc, hk, dh)
        v2 = (enc @ wv2).reshape(b, s_enc, hk, dh)
        k2, v2 = _match_kv_heads(hq, k2, v2, cfg, ctx)
        o2 = attention(q2, k2, v2, causal=False)
        o2 = o2.reshape(b, 1, hq * dh) @ wo2
        xd = xd + (ctx.psum(o2) if qs and tp > 1 else o2)
        y = apply_mlp(p["dec_mlp"], xd, cfg, ctx)
        return y, {"k": kc, "v": vc, "enc": enc}

    raise ValueError(kind)


def _ring_attend(q, kc, vc, pos, window, softcap):
    """Local attention over a ring cache of size `window`."""
    kpos_slot = jnp.arange(window)
    # slot i holds absolute position: largest P <= pos with P % window == i
    n_filled = jnp.minimum(pos + 1, window)
    abs_pos = pos - jnp.mod(pos - kpos_slot, window)
    valid = (abs_pos >= 0) & (abs_pos > pos - window) & (abs_pos <= pos)
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, jnp.repeat(kc, q.shape[2] // kc.shape[2], axis=2))
    logits = logits.astype(jnp.float32) / math.sqrt(q.shape[-1])
    logits = _softcap(logits, softcap)
    logits = jnp.where(valid[None, None, None, :], logits, -1e30)
    p = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", p, jnp.repeat(vc, q.shape[2] // vc.shape[2], axis=2))


# --------------------------------------------------------------------- #
# prefill: full sequence through one block, emitting the cache
# --------------------------------------------------------------------- #
def prefill_block(kind, p, x, cache, cfg, ctx: ShardCtx, positions):
    """x: (b, s, h) -> (y, cache).  Reuses the train forward, then fills the
    cache from the computed k/v (attention) or final state (recurrent)."""
    from .modules import _kv_sharded, _tp, apply_layer

    b, s, h = x.shape
    dh = cfg.get("head_dim") or h // cfg["n_heads"]
    hk = (
        cfg["n_kv_heads"] // _tp(cfg)
        if _kv_sharded(cfg)
        else cfg["n_kv_heads"]
    )

    y = apply_layer(kind, p, x, positions, cfg, ctx)

    if kind in ("attn", "attn_local", "encdec"):
        if kind == "encdec":
            pbase = p["dec_attn"]
            xsrc = x[:, cfg["s_enc"] :]
        else:
            pbase = p
            xsrc = x
        wk = pbase.get("wk", pbase.get("wk_rep"))
        wv = pbase.get("wv", pbase.get("wv_rep"))
        ssrc = xsrc.shape[1]
        xin = rmsnorm(pbase["ln"], xsrc)
        k = rope((xin @ wk).reshape(b, ssrc, hk, dh), positions[:ssrc])
        v = (xin @ wv).reshape(b, ssrc, hk, dh)
        Sc = cache["k"].shape[1]
        if Sc >= ssrc:
            kc = jax.lax.dynamic_update_slice(cache["k"], k, (0, 0, 0, 0))
            vc = jax.lax.dynamic_update_slice(cache["v"], v, (0, 0, 0, 0))
        else:  # windowed: keep the tail
            kc = k[:, ssrc - Sc :]
            vc = v[:, ssrc - Sc :]
        new = dict(cache)
        new["k"], new["v"] = kc, vc
        if kind == "encdec":
            new["enc"] = y[:, : cfg["s_enc"]]
        return y, new
    if kind == "mla":
        d_kv = cfg.get("kv_lora_rank") or 512
        xin = rmsnorm(p["ln"], x)
        ckv = xin @ p["wdkv"]
        c_, kr = ckv[..., :d_kv], rope(ckv[..., None, d_kv:], positions[:s])[:, :, 0]
        cc = jax.lax.dynamic_update_slice(cache["c"], c_, (0, 0, 0))
        krc = jax.lax.dynamic_update_slice(cache["kr"], kr, (0, 0, 0))
        return y, {"c": cc, "kr": krc}
    # recurrent kinds: run the decode recurrence once over the sequence to
    # produce the final state (prefill roofline is dominated by the forward).
    if kind in ("slstm", "mlstm", "rglru"):
        def step(cc, t):
            xt = jax.lax.dynamic_slice_in_dim(x, t, 1, axis=1)
            _, nc = decode_block(kind, p, xt, cc, t, cfg, ctx)
            return nc, None

        cache, _ = jax.lax.scan(step, cache, jnp.arange(s))
        return y, cache
    return y, cache


# --------------------------------------------------------------------- #
# serve chunk: the per-stage layer group, cache-threaded
# --------------------------------------------------------------------- #
def build_serve_program(cfg: ArchConfig, spec: RunSpec, placement, mode: str):
    """Returns (InferProgram, cache_init(b, S) for one stage, one group)."""
    from ..core.infer_executor import InferProgram
    from .lm import make_src

    ctx = ShardCtx(tp_axis=spec.tp_axis, tp_size=spec.tp_size)
    chunk_fn, cache_init, cache_pspecs = make_serve_chunk(cfg, spec, mode)
    src_train, _ = make_src(cfg, ctx)

    def src(shared, side_mb):
        if mode == "decode":
            from .lm import _embed_lookup

            return _embed_lookup(shared, side_mb["tokens"], cfg, ctx)
        return src_train(shared, side_mb)

    def sink(shared, y, side_mb):
        yl = y[:, -1:]  # next-token logits from the last position
        yn = rmsnorm(shared["final_ln"], yl)
        return (yn @ shared["head"])[:, 0]

    if mode == "decode":
        s_total = 1
    else:
        s_total = spec.seq_len
        ex = cfg.extras_dict()
        if cfg.family == "encdec":
            s_total += ex["s_enc"]
        elif cfg.family == "vlm":
            s_total += ex["n_patches"]

    from .modules import pad_to_multiple

    v_l = pad_to_multiple(cfg.vocab, max(1, spec.tp_size)) // max(1, spec.tp_size)
    program = InferProgram(
        chunk_fns=[chunk_fn] * spec.n_chunks,
        src=src,
        sink=sink,
        act_shape=(spec.microbatch, s_total, cfg.d_model),
        act_dtype=cfg.jdtype(),
        out_shape=(spec.microbatch, v_l),
        out_dtype=cfg.jdtype(),
    )
    return program, cache_init, cache_pspecs


def make_serve_chunk(cfg: ArchConfig, spec: RunSpec, mode: str):
    """Returns (chunk_fn(params, x, side, cache, pos) -> (y, cache),
    cache_init(b, S) -> pytree) for one chunk."""
    ctx = ShardCtx(tp_axis=spec.tp_axis, tp_size=spec.tp_size)
    blocks, g = group_layout(cfg, spec.p, spec.n_chunks)
    lcfg = layer_cfg(cfg, spec.tp_size)

    def cache_init(b: int, S: int):
        return tuple(
            tuple(
                cache_spec(kind, lcfg, ctx, b, S, cfg.jdtype()) for kind in kinds
            )
            for kinds in blocks
        )

    def cache_pspecs(tp_axis):
        return tuple(
            tuple(cache_pspec(kind, lcfg, tp_axis) for kind in kinds)
            for kinds in blocks
        )

    def chunk_fn(params, x, side, cache, pos):
        new_cache = []
        for bi, kinds in enumerate(blocks):
            mask = params["mask"][bi].astype(x.dtype)
            xb = x
            kc = []
            for ki, kind in enumerate(kinds):
                if mode == "decode":
                    xb, c2 = decode_block(
                        kind, params["blocks"][bi][ki], xb, cache[bi][ki], pos, lcfg, ctx
                    )
                else:
                    xb, c2 = prefill_block(
                        kind,
                        params["blocks"][bi][ki],
                        xb,
                        cache[bi][ki],
                        lcfg,
                        ctx,
                        side["positions"],
                    )
                kc.append(c2)
            x = mask * xb + (1.0 - mask) * x
            new_cache.append(tuple(kc))
        return x, tuple(new_cache)

    return chunk_fn, cache_init, cache_pspecs


# --------------------------------------------------------------------- #
# context-parallel prefill (beyond-paper; EXPERIMENTS.md Perf iter 3)
# --------------------------------------------------------------------- #
def prefill_block_cp(kind, p, x_loc, cfg, ctx: ShardCtx, q_offset, s_full):
    """Sequence-sharded prefill: x_loc is this rank's (b, s/cp, h) slice and
    every rank holds FULL weights (cfg built with tp_size=1).

    MLP/norms are per-token: zero collectives.  Attention computes local
    q/k/v and all-gathers only K and V -- for GQA that is 2 * (hk*dh)/h of an
    activation per block instead of two full-activation all-reduces: ~16x
    less wire traffic for ds-67b (hk*dh = h/8, TP would pay 4x act).

    Weights are replicated per rank (no TP memory sharding); at inference
    there is no optimizer state, so a 67B/16-stage stage (~8.4 GB bf16) fits
    v5e HBM.  Returns (y_loc, (k_loc, v_loc)) -- the cache stays seq-sharded.
    """
    from .modules import _attend_dense, apply_mlp, rope as _rope

    b, s_loc, h = x_loc.shape
    if kind == "mlp":
        return apply_mlp(p, x_loc, cfg, ctx), None
    if kind not in ("attn", "attn_local"):
        raise ValueError(f"context-parallel prefill: unsupported kind {kind}")
    window = cfg.get("window") if kind == "attn_local" else None
    hq, hk = cfg["n_heads"], cfg["n_kv_heads"]
    dh = cfg.get("head_dim") or h // hq
    wq = p.get("wq", p.get("wq_rep"))
    wk = p.get("wk", p.get("wk_rep"))
    wv = p.get("wv", p.get("wv_rep"))
    wo = p.get("wo", p.get("wo_rep"))
    xin = rmsnorm(p["ln"], x_loc)
    pos_loc = q_offset + jnp.arange(s_loc)
    q = _rope((xin @ wq).reshape(b, s_loc, hq, dh), pos_loc)
    k = _rope((xin @ wk).reshape(b, s_loc, hk, dh), pos_loc)
    v = (xin @ wv).reshape(b, s_loc, hk, dh)
    if ctx.tp_axis is not None:
        k_all = jax.lax.all_gather(k, ctx.tp_axis, axis=1, tiled=True)
        v_all = jax.lax.all_gather(v, ctx.tp_axis, axis=1, tiled=True)
    else:
        k_all, v_all = k, v
    rep = hq // hk
    if rep > 1:
        k_all = jnp.repeat(k_all, rep, axis=2)
        v_all = jnp.repeat(v_all, rep, axis=2)
    o = _attend_dense(
        q, k_all, v_all, True, window, cfg.get("attn_softcap"),
        q_offset=q_offset,
    ) if s_loc <= 2048 else _cp_chunked(q, k_all, v_all, window, cfg, q_offset)
    y = x_loc + o.reshape(b, s_loc, hq * dh) @ wo
    return y, {"k": k, "v": v}


def _cp_chunked(q, k_all, v_all, window, cfg, q_offset, block=1024):
    from .modules import _attend_dense

    b, s_loc, hq, dh = q.shape
    nb = -(-s_loc // block)

    @jax.checkpoint
    def one(args):
        qi, i = args
        return _attend_dense(
            qi, k_all, v_all, True, window, cfg.get("attn_softcap"),
            q_offset=q_offset + i * block,
        )

    qb = q.reshape(b, nb, block, hq, dh).transpose(1, 0, 2, 3, 4)
    _, out = jax.lax.scan(lambda _, a: (None, one(a)), None, (qb, jnp.arange(nb)))
    return out.transpose(1, 0, 2, 3, 4).reshape(b, s_loc, hq, dh)
