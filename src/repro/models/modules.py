"""Layer library: pure-jnp forwards + initializers for every assigned family.

All functions are plain ``f(params, x, ...) -> y`` JAX code; the F/B/W split
is obtained by wrapping whole pipeline chunks with ``auto_fbw`` (core.passes),
so nothing here needs a hand-written backward.

Tensor parallelism follows Megatron: column-parallel in-projections,
row-parallel out-projections with a ``psum`` over the TP axis.  Parameters
are initialized at *global* shapes; shard_map + the name-based rules in
launch/sharding_rules.py hand each rank its local shard.  Divisibility
decisions live here (``cfg["tp_size"]``):

  * q heads % tp != 0  -> attention fully replicated (params named *_rep,
    no out-psum); the MLP of the same block stays TP.  (gemma2 8H, whisper 6H)
  * kv heads % tp != 0 (but q ok) -> kv projections replicated; each rank
    dynamically selects the kv heads its local q heads map to.
  * MoE experts are padded to a multiple of tp; padded experts are masked
    out of the router.
  * recurrent kinds (sLSTM/mLSTM/RG-LRU) keep replicated weights (their
    states are elementwise; TP would buy little and cost collectives).

Families covered: dense GQA transformer (RoPE, local windows, logit
soft-capping), MLA (DeepSeek-V3), MoE (shared + routed top-k), xLSTM
(sLSTM + chunkwise mLSTM), RG-LRU (RecurrentGemma), encoder-decoder layers
(Whisper; concat-carry), and a vocab-parallel cross-entropy sink.
"""

from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

PyTree = Any

__all__ = [
    "ShardCtx",
    "init_layer",
    "apply_layer",
    "apply_block",
    "LAYER_KINDS",
    "rmsnorm",
    "rope",
    "attention",
    "vocab_parallel_ce",
    "pad_to_multiple",
]


@dataclasses.dataclass(frozen=True)
class ShardCtx:
    """Tensor-parallel context threaded through all layers."""

    tp_axis: Optional[str] = None  # mesh axis name, None = no TP
    tp_size: int = 1

    def psum(self, x):
        if self.tp_axis is None:
            return x
        return jax.lax.psum(x, self.tp_axis)

    def pmax(self, x):
        """Gradient-free pmax (used for softmax max-shift, which cancels
        analytically; jax has no differentiation rule for pmax)."""
        if self.tp_axis is None:
            return jax.lax.stop_gradient(x)
        axis = self.tp_axis

        @jax.custom_vjp
        def f(v):
            return jax.lax.pmax(v, axis)

        f.defvjp(lambda v: (f(v), None), lambda _, g: (jnp.zeros_like(g),))
        return f(x)

    def index(self):
        if self.tp_axis is None:
            return jnp.zeros((), jnp.int32)
        return jax.lax.axis_index(self.tp_axis)


def pad_to_multiple(n: int, k: int) -> int:
    return ((n + k - 1) // k) * k


def _tp(cfg) -> int:
    return int(cfg.get("tp_size", 1) or 1)


def _q_sharded(cfg) -> bool:
    return cfg["n_heads"] % _tp(cfg) == 0


def _kv_sharded(cfg) -> bool:
    return _q_sharded(cfg) and cfg["n_kv_heads"] % _tp(cfg) == 0


# --------------------------------------------------------------------- #
# primitives
# --------------------------------------------------------------------- #
def _normal(key, shape, scale, dtype):
    return (jax.random.normal(key, shape) * scale).astype(dtype)


def rmsnorm(g, x, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    return ((x32 * jax.lax.rsqrt(var + eps)) * (1.0 + g.astype(jnp.float32))).astype(
        x.dtype
    )


def rope(x, positions, theta=10000.0):
    """x: (b, s, h, d); positions: (s,) or (b, s)."""
    d = x.shape[-1]
    half = d // 2
    freqs = jnp.exp(
        -math.log(theta) * jnp.arange(0, half, dtype=jnp.float32) / half
    )
    if positions.ndim == 1:
        ang = positions[None, :, None].astype(jnp.float32) * freqs
        ang = ang[:, :, None, :]  # (1, s, 1, half)
    else:
        ang = positions[..., None].astype(jnp.float32) * freqs
        ang = ang[:, :, None, :]
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1
    )
    return out.astype(x.dtype)


def _softcap(x, cap):
    if cap is None or cap <= 0:
        return x
    return cap * jnp.tanh(x / cap)


# --------------------------------------------------------------------- #
# attention (dense for short sequences, q-block-chunked + remat for long
# sequences so activation memory stays O(s * d) per layer)
# --------------------------------------------------------------------- #
def _attend_dense(q, k, v, causal, window, softcap, q_offset=0):
    """q: (b, sq, hq, d); k/v: (b, sk, hq, d) head-matched -> (b, sq, hq, d)."""
    sq = q.shape[1]
    d = q.shape[-1]
    logits = jnp.einsum("bqhd,bkhd->bhqk", q, k).astype(jnp.float32) / math.sqrt(d)
    logits = _softcap(logits, softcap)
    qpos = jnp.arange(sq) + q_offset
    kpos = jnp.arange(k.shape[1])
    mask = jnp.ones((sq, k.shape[1]), bool)
    if causal:
        mask = mask & (kpos[None, :] <= qpos[:, None])
    if window is not None and window > 0:
        mask = mask & (kpos[None, :] > qpos[:, None] - window)
    logits = jnp.where(mask[None, None], logits, -1e30)
    probs = jax.nn.softmax(logits, axis=-1).astype(q.dtype)
    return jnp.einsum("bhqk,bkhd->bqhd", probs, v)


def _attend_chunked(q, k, v, causal, window, softcap, block=1024):
    """Scan over query blocks, remat inside: O(s*d) saved residuals."""
    b, s, hq, d = q.shape
    dv = v.shape[-1]  # may differ from d (MLA: qk 192, v 128)
    nb = -(-s // block)
    pad = nb * block - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
    qb = q.reshape(b, nb, block, hq, d).transpose(1, 0, 2, 3, 4)

    @jax.checkpoint
    def one_block(args):
        qi, i = args
        return _attend_dense(
            qi, k, v, causal, window, softcap, q_offset=i * block
        )

    def body(_, args):
        return None, one_block(args)

    _, out = jax.lax.scan(body, None, (qb, jnp.arange(nb)))
    out = out.transpose(1, 0, 2, 3, 4).reshape(b, nb * block, hq, dv)
    return out[:, :s]


def _match_kv_heads(q_heads_local, k, v, cfg, ctx: ShardCtx):
    """Expand/select kv heads so k/v carry one head per local q head."""
    hq, hk, tp = cfg["n_heads"], cfg["n_kv_heads"], _tp(cfg)
    group = hq // hk
    if _kv_sharded(cfg) or tp == 1:
        rep = q_heads_local // k.shape[2]
        if rep > 1:
            k = jnp.repeat(k, rep, axis=2)
            v = jnp.repeat(v, rep, axis=2)
        return k, v
    # q sharded, kv replicated: local q head i -> global q head
    # r*hq_l + i -> kv head (r*hq_l + i) // group
    r = ctx.index()
    gq = r * q_heads_local + jnp.arange(q_heads_local)
    sel = gq // group
    return jnp.take(k, sel, axis=2), jnp.take(v, sel, axis=2)


def attention(q, k, v, *, causal=True, window=None, softcap=None, block=1024):
    if q.shape[1] <= 2 * block:
        return _attend_dense(q, k, v, causal, window, softcap)
    return _attend_chunked(q, k, v, causal, window, softcap, block)


# --------------------------------------------------------------------- #
# dense attention + MLP
# --------------------------------------------------------------------- #
def init_attn(key, cfg, dtype):
    h, hq, hk = cfg["d_model"], cfg["n_heads"], cfg["n_kv_heads"]
    dh = cfg.get("head_dim") or h // hq
    qs, kvs = _q_sharded(cfg), _kv_sharded(cfg)
    ks = jax.random.split(key, 5)
    sc = 1.0 / math.sqrt(h)
    so = sc / math.sqrt(2 * cfg["n_layers"])
    return {
        "ln": jnp.zeros((h,), dtype),
        ("wq" if qs else "wq_rep"): _normal(ks[0], (h, hq * dh), sc, dtype),
        ("wk" if kvs else "wk_rep"): _normal(ks[1], (h, hk * dh), sc, dtype),
        ("wv" if kvs else "wv_rep"): _normal(ks[2], (h, hk * dh), sc, dtype),
        ("wo" if qs else "wo_rep"): _normal(ks[3], (hq * dh, h), so, dtype),
    }


def apply_attn(p, x, positions, cfg, ctx: ShardCtx, *, window=None):
    b, s, _ = x.shape
    tp = _tp(cfg)
    qs = _q_sharded(cfg)
    hq_l = cfg["n_heads"] // tp if qs else cfg["n_heads"]
    hk_l = cfg["n_kv_heads"] // tp if _kv_sharded(cfg) else cfg["n_kv_heads"]
    dh = cfg.get("head_dim") or cfg["d_model"] // cfg["n_heads"]
    xin = rmsnorm(p["ln"], x)
    wq = p.get("wq", p.get("wq_rep"))
    wk = p.get("wk", p.get("wk_rep"))
    wv = p.get("wv", p.get("wv_rep"))
    wo = p.get("wo", p.get("wo_rep"))
    q = (xin @ wq).reshape(b, s, hq_l, dh)
    k = (xin @ wk).reshape(b, s, hk_l, dh)
    v = (xin @ wv).reshape(b, s, hk_l, dh)
    q, k = rope(q, positions), rope(k, positions)
    k, v = _match_kv_heads(hq_l, k, v, cfg, ctx)
    o = attention(
        q, k, v, causal=True, window=window, softcap=cfg.get("attn_softcap")
    )
    o = o.reshape(b, s, hq_l * dh) @ wo
    return x + (ctx.psum(o) if qs and tp > 1 else o)


def init_mlp(key, cfg, dtype):
    h, f = cfg["d_model"], cfg["d_ff"]
    assert f % _tp(cfg) == 0, f"d_ff={f} not divisible by tp={_tp(cfg)}"
    ks = jax.random.split(key, 3)
    sc = 1.0 / math.sqrt(h)
    return {
        "ln": jnp.zeros((h,), dtype),
        "wu": _normal(ks[0], (h, f), sc, dtype),
        "wg": _normal(ks[1], (h, f), sc, dtype),
        "wd": _normal(ks[2], (f, h), sc / math.sqrt(2 * cfg["n_layers"]), dtype),
    }


def apply_mlp(p, x, cfg, ctx: ShardCtx):
    xin = rmsnorm(p["ln"], x)
    up = xin @ p["wu"]
    gate = jax.nn.silu(xin @ p["wg"])
    out = (up * gate) @ p["wd"]
    return x + (ctx.psum(out) if _tp(cfg) > 1 else out)


# -- MLA (DeepSeek-V3): latent-compressed attention ---------------------- #
def init_mla(key, cfg, dtype):
    h = cfg["d_model"]
    hq = cfg["n_heads"]
    assert _q_sharded(cfg), "MLA requires n_heads % tp == 0"
    dh = cfg.get("head_dim") or cfg["d_model"] // cfg["n_heads"]
    d_q = cfg.get("q_lora_rank") or 1536
    d_kv = cfg.get("kv_lora_rank") or 512
    d_rope = cfg.get("qk_rope_head_dim") or 64
    ks = jax.random.split(key, 8)
    sc = 1.0 / math.sqrt(h)
    return {
        "ln": jnp.zeros((h,), dtype),
        "wdq": _normal(ks[0], (h, d_q), sc, dtype),
        "wuq": _normal(ks[1], (d_q, hq * (dh + d_rope)), 1 / math.sqrt(d_q), dtype),
        "wdkv": _normal(ks[2], (h, d_kv + d_rope), sc, dtype),
        "wuk": _normal(ks[3], (d_kv, hq * dh), 1 / math.sqrt(d_kv), dtype),
        "wuv": _normal(ks[4], (d_kv, hq * dh), 1 / math.sqrt(d_kv), dtype),
        "wo": _normal(ks[5], (hq * dh, h), sc / math.sqrt(2 * cfg["n_layers"]), dtype),
    }


def apply_mla(p, x, positions, cfg, ctx: ShardCtx):
    b, s, _ = x.shape
    tp = _tp(cfg)
    hq = cfg["n_heads"] // tp
    dh = cfg.get("head_dim") or cfg["d_model"] // cfg["n_heads"]
    d_rope = cfg.get("qk_rope_head_dim") or 64
    d_kv = cfg.get("kv_lora_rank") or 512
    xin = rmsnorm(p["ln"], x)
    q_all = (xin @ p["wdq"]) @ p["wuq"]
    q_all = q_all.reshape(b, s, hq, dh + d_rope)
    q_nope, q_rope = q_all[..., :dh], q_all[..., dh:]
    ckv = xin @ p["wdkv"]  # (b, s, d_kv + d_rope); latent replicated over tp
    c, k_rope = ckv[..., :d_kv], ckv[..., d_kv:]
    k_nope = (c @ p["wuk"]).reshape(b, s, hq, dh)
    v = (c @ p["wuv"]).reshape(b, s, hq, dh)
    q_rope = rope(q_rope, positions)
    k_rope = rope(k_rope[:, :, None, :], positions)  # shared across heads
    q = jnp.concatenate([q_nope, q_rope], axis=-1)
    k = jnp.concatenate(
        [k_nope, jnp.broadcast_to(k_rope, (b, s, hq, d_rope))], axis=-1
    )
    o = attention(q, k, v, causal=True)
    o = o.reshape(b, s, hq * dh) @ p["wo"]
    return x + (ctx.psum(o) if tp > 1 else o)


# -- MoE: shared + routed top-k, experts sharded over the TP axis --------- #
def _e_pad(cfg) -> int:
    return pad_to_multiple(cfg["n_experts"], _tp(cfg))


def init_moe(key, cfg, dtype):
    h = cfg["d_model"]
    f = cfg["moe_d_ff"]
    e_p = _e_pad(cfg)
    n_sh = cfg.get("n_shared_experts", 0)
    ks = jax.random.split(key, 8)
    sc = 1.0 / math.sqrt(h)
    so = sc / math.sqrt(2 * cfg["n_layers"])
    params = {
        "ln": jnp.zeros((h,), dtype),
        "router": _normal(ks[0], (h, cfg["n_experts"]), sc, jnp.float32),
        "wu": _normal(ks[1], (e_p, h, f), sc, dtype),
        "wg": _normal(ks[2], (e_p, h, f), sc, dtype),
        "wd": _normal(ks[3], (e_p, f, h), so, dtype),
    }
    if n_sh:
        f_sh = f * n_sh
        assert f_sh % _tp(cfg) == 0
        params.update(
            {
                "swu": _normal(ks[4], (h, f_sh), sc, dtype),
                "swg": _normal(ks[5], (h, f_sh), sc, dtype),
                "swd": _normal(ks[6], (f_sh, h), so, dtype),
            }
        )
    return params


def _moe_route(p, tok, cfg):
    """Top-k routing with per-expert capacity positions (shared by both
    dispatch backends).  Returns (top_g, top_i, pos_nk, keep) all (N, k)."""
    e_p = _e_pad(cfg)
    k_top = cfg["topk"]
    logits = tok.astype(jnp.float32) @ p["router"]  # (N, E) real experts
    gates = jax.nn.softmax(logits, axis=-1)
    top_g, top_i = jax.lax.top_k(gates, k_top)  # (N, k)
    top_g = top_g / (jnp.sum(top_g, axis=-1, keepdims=True) + 1e-9)
    onehot = jax.nn.one_hot(top_i, e_p, dtype=jnp.float32)  # (N, k, E_p)
    # globally consistent per-expert slot positions: count assignments in
    # (n, k) order over the flattened stream so no two selections collide.
    n = onehot.shape[0]
    flat = onehot.reshape(n * k_top, e_p)
    pos_flat = jnp.cumsum(flat, axis=0) - flat
    pos_nk = jnp.sum(pos_flat * flat, axis=-1).reshape(n, k_top)
    return top_g, top_i, pos_nk, onehot


def _dispatch_einsum(tok, top_g, top_i, pos_nk, onehot, cap, e_l, ei, dtype):
    """Mesh-TF dense dispatch; O(N*k*cap) one-hot + O(N*E_l*cap*h) einsums.
    Reference implementation (exact, differentiable end-to-end)."""
    keep = pos_nk < cap
    pos_oh = jax.nn.one_hot(
        jnp.where(keep, pos_nk, cap).astype(jnp.int32), cap, dtype=jnp.float32
    )  # (N, k, cap) -- the expert axis is NOT materialized
    sel = (onehot * keep[..., None].astype(jnp.float32))  # (N, k, E_p)
    sel_l = jax.lax.dynamic_slice_in_dim(sel, ei, e_l, axis=2)
    disp_l = jnp.einsum("nke,nkc->nec", sel_l, pos_oh)
    comb_l = jnp.einsum("nke,nkc->nec", sel_l * top_g[..., None], pos_oh)
    xe = jnp.einsum("nec,nh->ech", disp_l, tok.astype(jnp.float32)).astype(dtype)
    return xe, comb_l


def apply_moe(p, x, cfg, ctx: ShardCtx):
    """Shared + routed top-k experts, capacity-bounded, EP over the TP axis.

    dispatch="scatter" (default): slot indices are scattered once
    (O(N*k)) and tokens are moved with gather/scatter-add -- no
    O(N*E*cap) dense tensors.  dispatch="einsum" keeps the Mesh-TF dense
    formulation as the differentiation-friendly oracle (tests assert both
    agree).  Router gradients flow through the combine weights either way.
    """
    b, s, h = x.shape
    tp = _tp(cfg)
    e = cfg["n_experts"]
    e_p = _e_pad(cfg)
    k_top = cfg["topk"]
    e_l = e_p // tp
    cap = cfg.get("capacity", None)
    if cap is None:
        cap = int(math.ceil(b * s * k_top / e * cfg.get("capacity_factor", 1.25)))
        cap = max(4, min(cap, b * s))
    xin = rmsnorm(p["ln"], x)
    tok = xin.reshape(b * s, h)
    n = tok.shape[0]
    top_g, top_i, pos_nk, onehot = _moe_route(p, tok, cfg)
    ei = ctx.index() * e_l

    if cfg.get("moe_dispatch", "scatter") == "einsum":
        xe, comb_l = _dispatch_einsum(
            tok, top_g, top_i, pos_nk, onehot, cap, e_l, ei, x.dtype
        )
        up = jnp.einsum("ech,ehf->ecf", xe, p["wu"])
        gate = jax.nn.silu(jnp.einsum("ech,ehf->ecf", xe, p["wg"]))
        out_e = jnp.einsum("ecf,efh->ech", up * gate, p["wd"])
        y = jnp.einsum("nec,ech->nh", comb_l, out_e.astype(jnp.float32))
    else:
        # scatter dispatch: flat slot = (expert - ei) * cap + pos
        loc_e = top_i - ei  # (N, k) local expert index (may be out of range)
        keep = (pos_nk < cap) & (loc_e >= 0) & (loc_e < e_l)
        flat = jnp.where(
            keep, loc_e * cap + pos_nk.astype(jnp.int32), e_l * cap
        ).astype(jnp.int32)  # sentinel row e_l*cap
        # inverse map: slot -> token row (sentinel n = zero row)
        inv = jnp.full((e_l * cap + 1,), n, jnp.int32)
        inv = inv.at[flat.reshape(-1)].set(
            jnp.broadcast_to(jnp.arange(n)[:, None], flat.shape).reshape(-1),
            mode="drop",
        )
        tok_pad = jnp.concatenate([tok, jnp.zeros((1, h), tok.dtype)], axis=0)
        xe = tok_pad[inv[:-1]].reshape(e_l, cap, h)
        up = jnp.einsum("ech,ehf->ecf", xe, p["wu"])
        gate = jax.nn.silu(jnp.einsum("ech,ehf->ecf", xe, p["wg"]))
        out_e = jnp.einsum("ecf,efh->ech", up * gate, p["wd"])
        # combine: gather each (n, k) selection's output and weight it
        out_flat = jnp.concatenate(
            [out_e.reshape(e_l * cap, h), jnp.zeros((1, h), out_e.dtype)], axis=0
        )
        picked = out_flat[flat]  # (N, k, h); sentinel row contributes zeros
        w = (top_g * keep.astype(jnp.float32)).astype(jnp.float32)
        y = jnp.einsum("nkh,nk->nh", picked.astype(jnp.float32), w)

    y = (ctx.psum(y) if tp > 1 else y).astype(x.dtype)
    if "swu" in p:
        up = tok @ p["swu"]
        gate = jax.nn.silu(tok @ p["swg"])
        sh = (up * gate) @ p["swd"]
        y = y + (ctx.psum(sh) if tp > 1 else sh)
    return x + y.reshape(b, s, h)


# -- xLSTM blocks (replicated weights; recurrent state is elementwise) ---- #
def init_slstm(key, cfg, dtype):
    h = cfg["d_model"]
    ks = jax.random.split(key, 5)
    sc = 1.0 / math.sqrt(h)
    return {
        "ln": jnp.zeros((h,), dtype),
        "si": _normal(ks[0], (h, h), sc, dtype),
        "sf": _normal(ks[1], (h, h), sc, dtype),
        "sz": _normal(ks[2], (h, h), sc, dtype),
        "sog": _normal(ks[3], (h, h), sc, dtype),
        "so": _normal(ks[4], (h, h), sc / math.sqrt(2 * cfg["n_layers"]), dtype),
    }


def apply_slstm(p, x, cfg, ctx: ShardCtx):
    """sLSTM: scalar-memory recurrence with exponential gating (stabilized)."""
    b, s, h = x.shape
    xin = rmsnorm(p["ln"], x)
    i_pre = (xin @ p["si"]).astype(jnp.float32)
    f_pre = (xin @ p["sf"]).astype(jnp.float32)
    z = jnp.tanh(xin @ p["sz"]).astype(jnp.float32)
    o = jax.nn.sigmoid(xin @ p["sog"]).astype(jnp.float32)

    def step(carry, t):
        c, n, m_ = carry
        i_t, f_t, z_t = i_pre[:, t], f_pre[:, t], z[:, t]
        m_new = jnp.maximum(f_t + m_, i_t)
        i_e = jnp.exp(i_t - m_new)
        f_e = jnp.exp(f_t + m_ - m_new)
        c = f_e * c + i_e * z_t
        n = f_e * n + i_e
        return (c, n, m_new), c / jnp.maximum(n, 1.0)

    init = (
        jnp.zeros((b, h), jnp.float32),
        jnp.zeros((b, h), jnp.float32),
        jnp.full((b, h), -1e30, jnp.float32),
    )
    _, hs = jax.lax.scan(step, init, jnp.arange(s))
    hs = hs.transpose(1, 0, 2).astype(x.dtype)  # (b, s, h)
    return x + ((o.astype(x.dtype) * hs) @ p["so"])


def init_mlstm(key, cfg, dtype):
    h = cfg["d_model"]
    nh = cfg["n_heads"]
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(h)
    return {
        "ln": jnp.zeros((h,), dtype),
        "mq": _normal(ks[0], (h, h), sc, dtype),
        "mk": _normal(ks[1], (h, h), sc, dtype),
        "mv": _normal(ks[2], (h, h), sc, dtype),
        "mfg": _normal(ks[3], (h, nh), sc, dtype),
        "mig": _normal(ks[4], (h, nh), sc, dtype),
        "mo": _normal(ks[5], (h, h), sc / math.sqrt(2 * cfg["n_layers"]), dtype),
    }


def apply_mlstm(p, x, cfg, ctx: ShardCtx, chunk=128):
    """mLSTM matrix memory in chunkwise-parallel (linear-attention) form."""
    b, s, h = x.shape
    nh = cfg["n_heads"]
    dh = h // nh
    xin = rmsnorm(p["ln"], x)
    q = (xin @ p["mq"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    k = (xin @ p["mk"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3) / math.sqrt(dh)
    v = (xin @ p["mv"]).reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    f_g = jax.nn.sigmoid((xin @ p["mfg"]).astype(jnp.float32)).transpose(0, 2, 1)
    i_g = jax.nn.sigmoid((xin @ p["mig"]).astype(jnp.float32)).transpose(0, 2, 1)

    nc = -(-s // chunk)
    pad = nc * chunk - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
        f_g = jnp.pad(f_g, ((0, 0), (0, 0), (0, pad)), constant_values=1.0)
        i_g = jnp.pad(i_g, ((0, 0), (0, 0), (0, pad)))
    sh = (b, nh, nc, chunk)
    qc = q.reshape(b, nh, nc, chunk, dh).transpose(2, 0, 1, 3, 4)
    kc = k.reshape(b, nh, nc, chunk, dh).transpose(2, 0, 1, 3, 4)
    vc = v.reshape(b, nh, nc, chunk, dh).transpose(2, 0, 1, 3, 4)
    fc = f_g.reshape(*sh).transpose(2, 0, 1, 3)
    ic = i_g.reshape(*sh).transpose(2, 0, 1, 3)

    @jax.checkpoint
    def chunk_step(C, args):
        qi, ki, vi, fi, ii = args
        logf = jnp.log(fi + 1e-6)  # (b, nh, c)
        cum = jnp.cumsum(logf, axis=-1)
        total = cum[..., -1:]
        decay = jnp.exp(cum[..., :, None] - cum[..., None, :])
        causal = jnp.tril(jnp.ones((qi.shape[-2], qi.shape[-2]), bool))
        att = jnp.einsum("bhqd,bhkd->bhqk", qi, ki).astype(jnp.float32)
        att = att * jnp.where(causal[None, None], decay, 0.0)
        att = att * ii[..., None, :]
        intra = jnp.einsum("bhqk,bhkd->bhqd", att.astype(qi.dtype), vi)
        qdecay = jnp.exp(cum)[..., None]
        inter = jnp.einsum(
            "bhqd,bhde->bhqe",
            (qi.astype(jnp.float32) * qdecay).astype(qi.dtype),
            C,
        )
        kdecay = jnp.exp(total - cum)[..., None] * ii[..., None]
        Cn = C * jnp.exp(total)[..., None].astype(C.dtype) + jnp.einsum(
            "bhkd,bhke->bhde",
            (ki.astype(jnp.float32) * kdecay).astype(ki.dtype),
            vi,
        )
        return Cn, intra + inter

    C0 = jnp.zeros((b, nh, dh, dh), x.dtype)
    _, out = jax.lax.scan(chunk_step, C0, (qc, kc, vc, fc, ic))
    out = out.transpose(1, 2, 0, 3, 4).reshape(b, nh, nc * chunk, dh)
    out = out[:, :, :s].transpose(0, 2, 1, 3).reshape(b, s, h)
    return x + (out @ p["mo"])


# -- RG-LRU (RecurrentGemma) ---------------------------------------------- #
def init_rglru(key, cfg, dtype):
    h = cfg["d_model"]
    d_r = cfg.get("lru_width") or h
    ks = jax.random.split(key, 6)
    sc = 1.0 / math.sqrt(h)
    return {
        "ln": jnp.zeros((h,), dtype),
        "rx": _normal(ks[0], (h, d_r), sc, dtype),
        "ry": _normal(ks[1], (h, d_r), sc, dtype),
        "ra": _normal(ks[2], (d_r, d_r), 1 / math.sqrt(d_r), dtype),
        "ri": _normal(ks[3], (d_r, d_r), 1 / math.sqrt(d_r), dtype),
        "lam": jnp.full((d_r,), 2.0, jnp.float32),
        "ro": _normal(ks[4], (d_r, h), sc / math.sqrt(2 * cfg["n_layers"]), dtype),
    }


def apply_rglru(p, x, cfg, ctx: ShardCtx):
    """Gated linear recurrence: h_t = a_t * h_{t-1} + gated_t.

    Two recurrence forms, selected by ``cfg["rglru_scan"]``:

      * ``"associative"`` (default): ``lax.associative_scan`` -- the
        TPU-parallel log-depth form.  Its backward is a log-depth
        slice/concat graph with *no* ``scan`` equation, so the recurrent
        B/W split (core/passes.py) cannot recurse into a body; the dp slice
        (the ``lam`` gate-scale grad) is instead handled by the generic
        byte-minimal cut -- the "scanified dp fallback" is simply not
        needing one.
      * ``"sequential"``: an explicit ``lax.scan`` over time.  This routes
        the recurrence through the scan-split path (dx-only B scan; any
        dp-only outputs replayed at W), and keeps the backward graph
        O(s) instead of O(s log s) -- preferable for very long sequences.
    """
    b, s, h = x.shape
    xin = rmsnorm(p["ln"], x)
    u = xin @ p["rx"]
    gate_y = jax.nn.gelu(xin @ p["ry"])
    r = jax.nn.sigmoid((u @ p["ra"]).astype(jnp.float32))
    i = jax.nn.sigmoid((u @ p["ri"]).astype(jnp.float32))
    log_a = -8.0 * jax.nn.softplus(p["lam"]) * r
    a = jnp.exp(log_a)
    gated = (jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * i) * u.astype(
        jnp.float32
    )

    if cfg.get("rglru_scan", "associative") == "sequential":
        def step(hc, ag):
            a_t, g_t = ag
            hn = a_t * hc + g_t
            return hn, hn

        _, hs_t = jax.lax.scan(
            step,
            jnp.zeros((b, a.shape[-1]), jnp.float32),
            (a.transpose(1, 0, 2), gated.transpose(1, 0, 2)),
        )
        hs = hs_t.transpose(1, 0, 2)
    else:
        def combine(l, r_):
            a1, h1 = l
            a2, h2 = r_
            return a1 * a2, a2 * h1 + h2

        _, hs = jax.lax.associative_scan(combine, (a, gated), axis=1)
    y = (hs.astype(x.dtype) * gate_y) @ p["ro"]
    return x + y


# -- encoder/decoder joint layer (Whisper; concat-carry) ------------------- #
def init_encdec(key, cfg, dtype):
    ks = jax.random.split(key, 4)
    return {
        "enc_attn": init_attn(ks[0], cfg, dtype),
        "enc_mlp": init_mlp(ks[1], cfg, dtype),
        "dec_attn": init_attn(ks[2], cfg, dtype),
        "dec_mlp": init_mlp(ks[3], cfg, dtype),
        "xattn": init_attn(jax.random.fold_in(key, 9), cfg, dtype),
        "enc_on": jnp.ones((), dtype),
        "dec_on": jnp.ones((), dtype),
    }


def _attn_proj(p, cfg):
    return (
        p.get("wq", p.get("wq_rep")),
        p.get("wk", p.get("wk_rep")),
        p.get("wv", p.get("wv_rep")),
        p.get("wo", p.get("wo_rep")),
    )


def apply_encdec(p, x, positions, cfg, ctx: ShardCtx):
    """x = concat(enc_seq, dec_seq); per-stage masks pick enc / dec role."""
    s_enc = cfg["s_enc"]
    xe, xd = x[:, :s_enc], x[:, s_enc:]
    b = x.shape[0]
    tp = _tp(cfg)
    qs = _q_sharded(cfg)
    hq_l = cfg["n_heads"] // tp if qs else cfg["n_heads"]
    hk_l = cfg["n_kv_heads"] // tp if _kv_sharded(cfg) else cfg["n_kv_heads"]
    dh = cfg["d_model"] // cfg["n_heads"]
    pe, pd = positions[:s_enc], positions[: x.shape[1] - s_enc]

    def enc_f(xe):
        h = xe
        wq, wk, wv, wo = _attn_proj(p["enc_attn"], cfg)
        hin = rmsnorm(p["enc_attn"]["ln"], h)
        q = rope((hin @ wq).reshape(b, s_enc, hq_l, dh), pe)
        k = rope((hin @ wk).reshape(b, s_enc, hk_l, dh), pe)
        v = (hin @ wv).reshape(b, s_enc, hk_l, dh)
        k, v = _match_kv_heads(hq_l, k, v, cfg, ctx)
        o = attention(q, k, v, causal=False)
        o = o.reshape(b, s_enc, -1) @ wo
        h = h + (ctx.psum(o) if qs and tp > 1 else o)
        return apply_mlp(p["enc_mlp"], h, cfg, ctx)

    xe = xe + p["enc_on"] * (enc_f(xe) - xe)

    def dec_f(xd, xe):
        h = apply_attn(p["dec_attn"], xd, pd, cfg, ctx)
        wq, wk, wv, wo = _attn_proj(p["xattn"], cfg)
        hin = rmsnorm(p["xattn"]["ln"], h)
        sd = h.shape[1]
        q = (hin @ wq).reshape(b, sd, hq_l, dh)
        k = (xe @ wk).reshape(b, s_enc, hk_l, dh)
        v = (xe @ wv).reshape(b, s_enc, hk_l, dh)
        k, v = _match_kv_heads(hq_l, k, v, cfg, ctx)
        o = attention(q, k, v, causal=False)
        o = o.reshape(b, sd, -1) @ wo
        h = h + (ctx.psum(o) if qs and tp > 1 else o)
        return apply_mlp(p["dec_mlp"], h, cfg, ctx)

    xd = xd + p["dec_on"] * (dec_f(xd, xe) - xd)
    return jnp.concatenate([xe, xd], axis=1)


# --------------------------------------------------------------------- #
# registry
# --------------------------------------------------------------------- #
LAYER_KINDS: Dict[str, Tuple] = {
    "attn": (init_attn, lambda p, x, pos, cfg, ctx: apply_attn(p, x, pos, cfg, ctx)),
    "attn_local": (
        init_attn,
        lambda p, x, pos, cfg, ctx: apply_attn(
            p, x, pos, cfg, ctx, window=cfg.get("window", 4096)
        ),
    ),
    "mlp": (init_mlp, lambda p, x, pos, cfg, ctx: apply_mlp(p, x, cfg, ctx)),
    "mla": (init_mla, apply_mla),
    "moe": (init_moe, lambda p, x, pos, cfg, ctx: apply_moe(p, x, cfg, ctx)),
    "slstm": (init_slstm, lambda p, x, pos, cfg, ctx: apply_slstm(p, x, cfg, ctx)),
    "mlstm": (init_mlstm, lambda p, x, pos, cfg, ctx: apply_mlstm(p, x, cfg, ctx)),
    "rglru": (init_rglru, lambda p, x, pos, cfg, ctx: apply_rglru(p, x, cfg, ctx)),
    "encdec": (init_encdec, apply_encdec),
}


def init_layer(kind: str, key, cfg, ctx: ShardCtx, dtype):
    del ctx  # params are global-shaped; sharding comes from specs
    return LAYER_KINDS[kind][0](key, cfg, dtype)


def apply_layer(kind: str, params, x, positions, cfg, ctx: ShardCtx):
    return LAYER_KINDS[kind][1](params, x, positions, cfg, ctx)


def apply_block(
    kinds: Tuple[str, ...], mask, params, x, positions, cfg, ctx: ShardCtx
):
    """One architectural block (possibly several sub-kinds) with its padding
    mask folded in: padded blocks are exact no-ops with zero gradients.

    This is the unit the F/B/W split operates on: each block becomes its own
    split-VJP module (models/lm.py), so B emits a compact per-block M_W
    context -- the dgrad/wgrad pair of every kind falls out of the backward
    jaxpr partition in core/passes.py rather than a hand-written table.
    """
    xb = x
    for ki, kind in enumerate(kinds):
        xb = apply_layer(kind, params[ki], xb, positions, cfg, ctx)
    m = mask.astype(x.dtype)
    return m * xb + (1.0 - m) * x


# --------------------------------------------------------------------- #
# vocab-parallel cross entropy (sink)
# --------------------------------------------------------------------- #
def vocab_parallel_ce(logits_loc, labels, ctx: ShardCtx, vocab: int):
    """logits_loc: (b, s, V_pad/tp) this rank's vocab shard; labels: (b, s)."""
    v_l = logits_loc.shape[-1]
    off = ctx.index() * v_l
    z = logits_loc.astype(jnp.float32)
    zmax = ctx.pmax(jnp.max(z, axis=-1))  # gradient-free max-shift
    z = z - zmax[..., None]
    sumexp = ctx.psum(jnp.sum(jnp.exp(z), axis=-1))
    local_lab = labels - off
    in_range = (local_lab >= 0) & (local_lab < v_l)
    safe = jnp.clip(local_lab, 0, v_l - 1)
    picked = jnp.take_along_axis(z, safe[..., None], axis=-1)[..., 0]
    picked = ctx.psum(jnp.where(in_range, picked, 0.0))
    return jnp.mean(jnp.log(sumexp) - picked)
