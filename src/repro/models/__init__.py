from .lm import ArchConfig, RunSpec, build_program, init_params
from .modules import ShardCtx

__all__ = ["ArchConfig", "RunSpec", "build_program", "init_params", "ShardCtx"]
