"""Pipelined serving launcher (prefill + decode loop).

  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  python -m repro.launch.serve --arch internlm2_1_8b --reduced \
      --pipe-size 4 --groups 8 --new-tokens 4
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from ..configs import get_config, get_reduced
from ..core.schedules.ir import Placement
from ..models.lm import RunSpec, init_params, side_inputs
from .mesh import AxisBinding
from .steps import build_serve_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--pipe-size", type=int, default=4)
    ap.add_argument("--groups", type=int, default=8)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--prompt-len", type=int, default=24)
    ap.add_argument("--new-tokens", type=int, default=4)
    args = ap.parse_args()

    cfg = get_reduced(args.arch) if args.reduced else get_config(args.arch)
    p, m, b = args.pipe_size, args.groups, args.batch
    s_ctx = args.prompt_len + args.new_tokens
    placement = Placement.linear(p)
    spec = RunSpec(p=p, n_chunks=1, microbatch=b, seq_len=args.prompt_len, m=m)
    mesh = jax.make_mesh((p,), ("data",))
    binding = AxisBinding(pipe="data", tp=None, dp=None)

    make_p, _, cache_init = build_serve_step(
        cfg, spec, placement, mesh, binding, "prefill", s_ctx
    )
    stacked, shared = init_params(cfg, spec, placement)
    one = cache_init(b, s_ctx)
    caches = [
        jax.tree_util.tree_map(lambda a: jnp.zeros((p, m) + a.shape, a.dtype), one)
    ]
    side = side_inputs(cfg, spec)
    prefill = make_p(stacked, shared, side, caches)
    t0 = time.time()
    logits, caches = prefill(stacked, shared, side, caches)
    print(f"prefill {m}x{b}x{args.prompt_len} tok: {time.time()-t0:.2f}s")

    toks = jnp.argmax(logits, -1)[..., None].astype(jnp.int32)
    for i in range(args.new_tokens):
        dspec = RunSpec(p=p, n_chunks=1, microbatch=b, seq_len=1, m=m)
        make_d, _, _ = build_serve_step(
            cfg, dspec, placement, mesh, binding, "decode", args.prompt_len + 1 + i
        )
        dside = {
            "tokens": toks,
            "positions": jnp.broadcast_to(jnp.arange(1), (m, 1)),
        }
        decode = make_d(stacked, shared, dside, caches)
        t0 = time.time()
        logits, caches = decode(stacked, shared, dside, caches)
        toks = jnp.argmax(logits, -1)[..., None].astype(jnp.int32)
        print(f"decode step {i}: {m*b} tokens, {time.time()-t0:.3f}s")
    print("OK")


if __name__ == "__main__":
    main()
