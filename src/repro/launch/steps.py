"""Full train/serve step builders: pipeline executor + DP + post-validated
optimizer under one shard_map.  Shared by train.py, dryrun.py and tests."""

from __future__ import annotations

import dataclasses
from functools import partial
from typing import Any, Optional, Tuple

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from ..core.executor import PipelineExecutor
from ..core.infer_executor import InferExecutor, compile_infer_plan
from ..core.schedules.ir import ExecutionPlan, Placement
from ..models.lm import ArchConfig, RunSpec, build_program
from ..models.serve import build_serve_program
from ..optim import adamw, postval
from .mesh import AxisBinding
from .sharding_rules import shared_param_specs, stacked_param_specs

PyTree = Any

__all__ = ["TrainStepConfig", "build_train_step", "build_serve_step", "param_specs"]


@dataclasses.dataclass(frozen=True)
class TrainStepConfig:
    adamw: adamw.AdamWConfig = dataclasses.field(default_factory=adamw.AdamWConfig)
    postval_mode: str = "within_step"  # "within_step" | "sync" (baseline)
    grad_compress: str = "none"  # "none" | "bf16" | "int8" (dp all-reduce)
    unroll: bool = False
    prune_channels: bool = True
    shard_channels: bool = False  # seq-shard pipe sends over tp (Perf log)
    # executor compilation mode (DESIGN.md Sec. 8): "scan" (generic tick in
    # lax.scan), "unroll" (generic tick unrolled), or "specialized"
    # (trace-time specialization against the static plan: direct branch
    # calls, exact-edge permutes, steady-state scan superstep).  None keeps
    # the legacy `unroll` bool semantics.
    executor_mode: Optional[str] = None
    # donate params/opt state to the jitted step (they are consumed and
    # re-emitted every step, so aliasing them halves the peak param+moment
    # traffic); callers that re-read the input arrays after stepping must
    # opt out.
    donate: bool = True


def param_specs(stacked, shared, binding: AxisBinding):
    """Per-leaf PartitionSpecs: stage axis over pipe + Megatron TP dims
    (launch/sharding_rules.py); shared params vocab/tp-sharded."""
    stacked_spec = stacked_param_specs(stacked, binding.pipe, binding.tp)
    shared_spec = shared_param_specs(shared, binding.tp)
    return stacked_spec, shared_spec


def _freeze_filter(tree, path_key="mask"):
    """Bool tree: True = frozen (structural masks are not trainable)."""
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        frozen = any(
            getattr(k, "key", None) == path_key for k in path
        )
        out.append(frozen)
    return jax.tree_util.tree_unflatten(treedef, out)


def build_train_step(
    cfg: ArchConfig,
    spec: RunSpec,
    plan: ExecutionPlan,
    placement: Placement,
    mesh,
    binding: AxisBinding,
    tcfg: Optional[TrainStepConfig] = None,
):
    """Returns (step_fn, in_specs, out_specs).

    step_fn(stacked_params, shared, opt_state, shared_opt, side) ->
      (stacked_params, shared, opt_state, shared_opt, metrics)
    """
    tcfg = tcfg or TrainStepConfig()
    program = build_program(cfg, spec, placement)
    execu = PipelineExecutor(
        program,
        plan,
        pipe_axis=binding.pipe,
        unroll=tcfg.unroll,
        prune_channels=tcfg.prune_channels,
        tp_axis=binding.tp,
        shard_channels=tcfg.shard_channels,
        tp_size=binding.sizes(mesh)[1],
        mode=tcfg.executor_mode,
    )
    grad_fn = execu.build_grad_fn()
    p, tp, dp = binding.sizes(mesh)
    acfg = tcfg.adamw

    def body(stacked, shared, opt_state, shared_opt, side):
        unstack = lambda tree: jax.tree_util.tree_map(lambda a: a[0], tree)
        local = tuple(unstack(sp) for sp in stacked)
        opt_state = adamw.AdamWState(
            t=opt_state.t,
            m=tuple(unstack(x) for x in opt_state.m),
            v=tuple(unstack(x) for x in opt_state.v),
        )
        grads, shared_grads, loss = grad_fn(local, shared, side)

        if binding.dp is not None:
            if tcfg.grad_compress != "none":
                from ..optim.compress import compressed_psum

                grads, _ = compressed_psum(grads, binding.dp, tcfg.grad_compress)
                shared_grads, _ = compressed_psum(
                    shared_grads, binding.dp, tcfg.grad_compress
                )
            else:
                grads = jax.lax.psum(grads, binding.dp)
                shared_grads = jax.lax.psum(shared_grads, binding.dp)
            loss = jax.lax.psum(loss, binding.dp)
            scale = 1.0 / dp
            grads = jax.tree_util.tree_map(lambda g: g * scale, grads)
            shared_grads = jax.tree_util.tree_map(
                lambda g: g * scale, shared_grads
            )
            loss = loss * scale

        # freeze structural masks
        frozen = _freeze_filter(local)
        grads = jax.tree_util.tree_map(
            lambda g, f: jnp.zeros_like(g) if f else g, grads, frozen
        )

        # gradient statistics: shared params counted on stage 0 only
        sidx = jax.lax.axis_index(binding.pipe)
        stats_local = postval.local_stats(grads)
        stats_shared = postval.local_stats(shared_grads)
        on0 = (sidx == 0).astype(jnp.float32)
        stats = postval.GradStats(
            stats_local.sumsq + on0 * stats_shared.sumsq,
            stats_local.nonfinite | ((on0 > 0) & stats_shared.nonfinite),
        )

        both_params = (local, shared)
        both_grads = (grads, shared_grads)
        state = adamw.AdamWState(
            t=opt_state.t,
            m=(opt_state.m, shared_opt.m),
            v=(opt_state.v, shared_opt.v),
        )

        if tcfg.postval_mode == "sync":
            # baseline: blocking global reduction before the step
            g_stats = postval.GradStats(
                jax.lax.psum(stats.sumsq, binding.pipe),
                jax.lax.psum(
                    stats.nonfinite.astype(jnp.float32), binding.pipe
                )
                > 0.5,
            )
            new_params, new_state = postval.sync_step(
                both_params, state, both_grads, acfg, g_stats
            )
            amended = jnp.zeros((), bool)
        else:
            partial_s, full_s = postval.pipe_prefix_stats(stats, binding.pipe)
            p1, s1, dec = postval.optimistic_step(
                both_params, state, both_grads, partial_s, acfg
            )
            new_params, new_state, amended = postval.validate_and_fix(
                p1, s1, both_grads, dec, full_s, acfg
            )

        new_local, new_shared = new_params
        restack = lambda tree: jax.tree_util.tree_map(lambda a: a[None], tree)
        new_opt = adamw.AdamWState(
            t=new_state.t,
            m=tuple(restack(x) for x in new_state.m[0]),
            v=tuple(restack(x) for x in new_state.v[0]),
        )
        new_shared_opt = adamw.AdamWState(
            t=new_state.t, m=new_state.m[1], v=new_state.v[1]
        )
        # shared params must stay replicated over pipe: they already are
        # (identical math on every stage).
        new_stacked = tuple(
            jax.tree_util.tree_map(lambda a: a[None], sp) for sp in new_local
        )
        metrics = {
            "loss": loss,
            "grad_norm": jnp.sqrt(
                jax.lax.psum(stats.sumsq, binding.pipe)
            ),
            "amended": amended,
        }
        return new_stacked, new_shared, new_opt, new_shared_opt, metrics

    stacked_sdt, shared_sdt = _abstract_params(cfg, spec, placement)
    stacked_spec, shared_spec = param_specs(stacked_sdt, shared_sdt, binding)
    opt_spec = adamw.AdamWState(
        t=P(), m=stacked_spec, v=stacked_spec
    )
    shared_opt_spec = adamw.AdamWState(t=P(), m=shared_spec, v=shared_spec)
    side_spec = P(binding.dp) if binding.dp else P()
    metrics_spec = {"loss": P(), "grad_norm": P(), "amended": P()}

    in_specs = (stacked_spec, shared_spec, opt_spec, shared_opt_spec, side_spec)
    out_specs = (stacked_spec, shared_spec, opt_spec, shared_opt_spec, metrics_spec)

    def _side_tree_spec(side):
        return jax.tree_util.tree_map(lambda _: side_spec, side)

    def make(side_example):
        fn = shard_map(
            body,
            mesh=mesh,
            in_specs=(
                stacked_spec,
                shared_spec,
                opt_spec,
                shared_opt_spec,
                _side_tree_spec(side_example),
            ),
            out_specs=out_specs,
            check_rep=False,
        )
        # params/opt moments are pure pass-through state: donating them lets
        # XLA update in place instead of double-buffering every leaf.
        donate = (0, 1, 2, 3) if tcfg.donate else ()
        return jax.jit(fn, donate_argnums=donate)

    return make, (in_specs, out_specs)


def _abstract_params(cfg, spec, placement):
    from ..models.lm import init_params

    return jax.eval_shape(lambda: init_params(cfg, spec, placement))


def build_serve_step(
    cfg: ArchConfig,
    spec: RunSpec,
    placement: Placement,
    mesh,
    binding: AxisBinding,
    mode: str,
    cache_len: int,
    donate: bool = True,
):
    """Returns (make(side, caches) -> jitted step, program, cache_init).

    ``donate`` aliases the KV caches into the step (they are consumed and
    re-emitted every call), halving the steady-state cache footprint.
    """
    program, cache_init, cache_pspecs = build_serve_program(cfg, spec, placement, mode)
    plan = compile_infer_plan(placement, spec.m)
    execu = InferExecutor(program, plan, pipe_axis=binding.pipe)
    step = execu.build_step_fn()
    pos = cache_len - 1 if mode == "decode" else 0

    def body(stacked, shared, side, caches):
        local = tuple(jax.tree_util.tree_map(lambda a: a[0], sp) for sp in stacked)
        local_caches = [
            jax.tree_util.tree_map(lambda a: a[0], c) for c in caches
        ]
        out, newc = step(local, shared, side, local_caches, pos)
        newc = [jax.tree_util.tree_map(lambda a: a[None], c) for c in newc]
        return out, newc

    def make(stacked_sdt, shared_sdt, side_example, caches_sdt):
        stacked_spec, shared_spec = param_specs(stacked_sdt, shared_sdt, binding)
        side_spec = jax.tree_util.tree_map(
            lambda _: P(binding.dp) if binding.dp else P(), side_example
        )
        kind_specs = cache_pspecs(binding.tp)
        cache_spec = [
            jax.tree_util.tree_map(
                lambda sd, ks: P(binding.pipe, None, *ks),
                c,
                kind_specs,
                is_leaf=lambda x: isinstance(
                    x, (jax.ShapeDtypeStruct, jax.Array)
                ) or hasattr(x, "shape"),
            )
            for c in caches_sdt
        ]
        out_spec = P(binding.dp) if binding.dp else P()
        fn = shard_map(
            body,
            mesh=mesh,
            in_specs=(stacked_spec, shared_spec, side_spec, cache_spec),
            out_specs=(out_spec, cache_spec),
            check_rep=False,
        )
        return jax.jit(fn, donate_argnums=(3,) if donate else ())

    return make, program, cache_init
