"""Per-leaf PartitionSpec inference for model parameters.

Parameters are initialized at *global* shapes (ShardCtx(tp_size=1)); the
rules here place the TP axis on the Megatron-correct dimension per leaf name:

  column-parallel (out-features sharded): wq wk wv wu wg wuq wuk wuv swu swg head
  row-parallel  (in-features sharded):    wo wd swd
  expert-parallel (expert dim sharded):   moe wu/wg/wd (ndim 3 before stacking)
  vocab-parallel (rows sharded):          embed
  replicated:                             norms, router, masks, biases, lam

shard_map then hands each (stage, tp-rank) exactly the local shard the layer
code expects (layers compute local head counts / expert counts from
ShardCtx(tp_size)).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import PartitionSpec as P

PyTree = Any

__all__ = ["stacked_param_specs", "shared_param_specs", "leaf_name"]

_COL = {"wq", "wk", "wv", "wu", "wg", "wuq", "wuk", "wuv", "swu", "swg"}
_ROW = {"wo", "wd", "swd"}


def leaf_name(path) -> str:
    name = ""
    for k in path:
        if hasattr(k, "key") and isinstance(getattr(k, "key"), str):
            name = k.key
    return name


def _spec_for(name: str, ndim: int, tp: Optional[str], lead_axes) -> P:
    """lead_axes: tuple of axis names occupying the leading dims (e.g. the
    stage-stack axis), or () for shared params."""
    nl = len(lead_axes)
    body = ndim - nl
    parts = list(lead_axes)
    if tp is None or body == 0:
        return P(*parts) if parts else P()
    if name in _COL:
        if body == 3:  # MoE expert weights (e, h, f): shard experts
            parts += [tp] + [None] * (body - 1)
        else:
            parts += [None] * (body - 1) + [tp]
    elif name in _ROW:
        if body == 3:  # MoE down-proj (e, f, h): shard experts
            parts += [tp] + [None] * (body - 1)
        else:
            parts += [tp] + [None] * (body - 1)
    elif name == "embed":
        parts += [tp] + [None] * (body - 1)
    elif name == "head":
        parts += [None] * (body - 1) + [tp]
    else:
        parts += [None] * body
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def stacked_param_specs(stacked: PyTree, pipe: str, tp: Optional[str]) -> PyTree:
    """Specs for per-chunk stage-stacked params: leading dim over pipe."""
    def one(tree):
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        specs = [
            _spec_for(leaf_name(path), leaf.ndim, tp, (pipe,))
            for path, leaf in flat
        ]
        return jax.tree_util.tree_unflatten(treedef, specs)

    return tuple(one(t) for t in stacked)


def shared_param_specs(shared: PyTree, tp: Optional[str]) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(shared)
    specs = [
        _spec_for(leaf_name(path), leaf.ndim, tp, ()) for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)
