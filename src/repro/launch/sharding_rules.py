"""Per-leaf PartitionSpec inference for model parameters.

Parameters are initialized at *global* shapes (ShardCtx(tp_size=1)); the
rules here place the TP axis on the Megatron-correct dimension per leaf name:

  column-parallel (out-features sharded): wq wk wv wu wg wuq wuk wuv swu swg head
  row-parallel  (in-features sharded):    wo wd swd
  expert-parallel (expert dim sharded):   moe wu/wg/wd (ndim 3 before stacking)
  vocab-parallel (rows sharded):          embed
  replicated:                             norms, router, masks, biases, lam

shard_map then hands each (stage, tp-rank) exactly the local shard the layer
code expects (layers compute local head counts / expert counts from
ShardCtx(tp_size)).
"""

from __future__ import annotations

from typing import Any, Optional

import jax
from jax.sharding import PartitionSpec as P

PyTree = Any

__all__ = [
    "stacked_param_specs",
    "shared_param_specs",
    "leaf_name",
    "local_leaf_shape",
    "tp_local_shapes",
]

_COL = {"wq", "wk", "wv", "wu", "wg", "wuq", "wuk", "wuv", "swu", "swg"}
_ROW = {"wo", "wd", "swd"}


def leaf_name(path) -> str:
    name = ""
    for k in path:
        if hasattr(k, "key") and isinstance(getattr(k, "key"), str):
            name = k.key
    return name


def _spec_for(name: str, ndim: int, tp: Optional[str], lead_axes) -> P:
    """lead_axes: tuple of axis names occupying the leading dims (e.g. the
    stage-stack axis), or () for shared params."""
    nl = len(lead_axes)
    body = ndim - nl
    parts = list(lead_axes)
    if tp is None or body == 0:
        return P(*parts) if parts else P()
    if name in _COL:
        if body == 3:  # MoE expert weights (e, h, f): shard experts
            parts += [tp] + [None] * (body - 1)
        else:
            parts += [None] * (body - 1) + [tp]
    elif name in _ROW:
        if body == 3:  # MoE down-proj (e, f, h): shard experts
            parts += [tp] + [None] * (body - 1)
        else:
            parts += [tp] + [None] * (body - 1)
    elif name == "embed":
        parts += [tp] + [None] * (body - 1)
    elif name == "head":
        parts += [None] * (body - 1) + [tp]
    else:
        parts += [None] * body
    while parts and parts[-1] is None:
        parts.pop()
    return P(*parts)


def stacked_param_specs(stacked: PyTree, pipe: str, tp: Optional[str]) -> PyTree:
    """Specs for per-chunk stage-stacked params: leading dim over pipe."""
    def one(tree):
        flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
        specs = [
            _spec_for(leaf_name(path), leaf.ndim, tp, (pipe,))
            for path, leaf in flat
        ]
        return jax.tree_util.tree_unflatten(treedef, specs)

    return tuple(one(t) for t in stacked)


def shared_param_specs(shared: PyTree, tp: Optional[str]) -> PyTree:
    flat, treedef = jax.tree_util.tree_flatten_with_path(shared)
    specs = [
        _spec_for(leaf_name(path), leaf.ndim, tp, ()) for path, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


# --------------------------------------------------------------------- #
# byte-exact local shapes (planner accounting)
# --------------------------------------------------------------------- #
def local_leaf_shape(shape, spec: P, axis_sizes) -> tuple:
    """The per-rank shard shape of one leaf under ``spec``.

    ``axis_sizes`` maps mesh axis name -> size.  Dimensions the spec leaves
    unsharded (or shards over an axis not in ``axis_sizes``) keep their
    global extent; sharded dims divide exactly when divisible and round up
    otherwise (the runtime pads before sharding).
    """
    out = list(shape)
    for d, part in enumerate(spec):
        if part is None:
            continue
        names = part if isinstance(part, tuple) else (part,)
        div = 1
        for nm in names:
            div *= int(axis_sizes.get(nm, 1))
        if div > 1:
            out[d] = -(-out[d] // div)
    return tuple(out)


def tp_local_shapes(tree: PyTree, tp_size: int, lead_axes=()) -> PyTree:
    """ShapeDtypeStructs of each leaf's *tp-local* shard, per these rules.

    Used by the planner to price params / optimizer state per leaf instead
    of uniformly dividing the tree total by the TP degree: replicated
    leaves (norm gains, routers, masks, ``lam``, ``*_rep`` projections when
    head counts do not divide tp) keep their full bytes on every rank.
    ``lead_axes`` names leading dims to leave untouched (e.g. the
    stage-stack axis).
    """
    tp_name = "_tp"
    sizes = {tp_name: max(1, int(tp_size))}
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        spec = _spec_for(
            leaf_name(path), leaf.ndim, tp_name if tp_size > 1 else None,
            tuple(lead_axes),
        )
        shp = local_leaf_shape(tuple(leaf.shape), spec, sizes)
        out.append(jax.ShapeDtypeStruct(shp, leaf.dtype))
    return jax.tree_util.tree_unflatten(treedef, out)
