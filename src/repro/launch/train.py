"""End-to-end training launcher.

Builds (config, schedule, mesh) -> jitted ZB train step -> fault-tolerant
driver loop with checkpointing.  Works on any mesh whose axis names match the
binding -- CPU test meshes (fake devices) and the production (16,16) /
(2,16,16) meshes alike.

Example (small CPU run, 4 fake devices):
  XLA_FLAGS=--xla_force_host_platform_device_count=4 \
  python -m repro.launch.train --arch internlm2_1_8b --reduced \
      --pipe-size 4 --steps 30 --schedule zb-h2
"""

from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp
import numpy as np

from ..configs import get_config, get_reduced
from ..core.schedules import (
    compile_plan,
    one_f_one_b,
    v_half,
    v_min,
    zb_1p,
    zb_2p,
    zb_h1,
    zb_h2,
    zb_v,
)
from ..data import DataConfig, SyntheticLM
from ..models.lm import RunSpec, init_params
from ..optim import adamw
from ..runtime import DriverConfig, TrainDriver
from .compile_cache import enable_persistent_cache
from .mesh import AxisBinding
from .steps import TrainStepConfig, build_train_step

SCHEDULES = {
    "1f1b": one_f_one_b,
    "zb-h1": zb_h1,
    "zb-h2": zb_h2,
    "zb-v": zb_v,
    "v-min": v_min,
    "v-half": v_half,
    "zb-1p": zb_1p,
    "zb-2p": zb_2p,
}




def build_everything(
    arch: str,
    reduced: bool,
    pipe_size: int,
    tp_size: int,
    schedule: str,
    microbatch: int,
    seq_len: int,
    m: int,
    tcfg: TrainStepConfig,
    mesh=None,
    binding=None,
    memory_budget_bytes=None,
):
    cfg = get_reduced(arch) if reduced else get_config(arch)
    if memory_budget_bytes is not None:
        from ..runtime.driver import replan_under_budget

        sched, report = replan_under_budget(
            cfg, pipe_size, m, microbatch, seq_len, memory_budget_bytes,
            tp_size=tp_size,
        )
        print(f"HBM planner: {report.summary()}")
        if report.chosen is not None and report.chosen.breakdown is not None:
            print("per-device HBM breakdown:")
            print(report.chosen.breakdown.report())
    else:
        sched = SCHEDULES[schedule](pipe_size, m)
    plan = compile_plan(sched)
    if mesh is None:
        axes = ("data",) if tp_size == 1 else ("data", "model")
        shape = (pipe_size,) if tp_size == 1 else (pipe_size, tp_size)
        mesh = jax.make_mesh(shape, axes)
        binding = AxisBinding(
            pipe="data", tp="model" if tp_size > 1 else None, dp=None
        )
    spec = RunSpec(
        p=pipe_size,
        n_chunks=sched.n_chunks,
        microbatch=microbatch,
        seq_len=seq_len,
        m=m,
        tp_axis=binding.tp,
        tp_size=tp_size,
    )
    make, _ = build_train_step(cfg, spec, plan, sched.placement, mesh, binding, tcfg)
    return cfg, spec, sched, make, mesh, binding


def side_from_batch(batch, spec, s_total_extra=None, cfg=None):
    m, b, s = spec.m, spec.microbatch, spec.seq_len
    tokens = jnp.asarray(batch["tokens"]).reshape(m, b, s)
    labels = jnp.asarray(batch["labels"]).reshape(m, b, s)
    side = {"tokens": tokens, "labels": labels}
    s_total = s
    if cfg is not None and cfg.family == "encdec":
        ex = cfg.extras_dict()
        side["frames"] = jnp.zeros(
            (m, b, ex["s_enc"], ex.get("frontend_dim", cfg.d_model)), cfg.jdtype()
        )
        s_total += ex["s_enc"]
    if cfg is not None and cfg.family == "vlm":
        ex = cfg.extras_dict()
        side["patches"] = jnp.zeros(
            (m, b, ex["n_patches"], ex.get("frontend_dim", cfg.d_model)), cfg.jdtype()
        )
        s_total += ex["n_patches"]
    side["positions"] = jnp.broadcast_to(jnp.arange(s_total), (m, s_total))
    return side


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="gpt3_1_5b")
    ap.add_argument("--reduced", action="store_true")
    ap.add_argument("--pipe-size", type=int, default=4)
    ap.add_argument("--tp-size", type=int, default=1)
    ap.add_argument("--schedule", default="zb-h2", choices=sorted(SCHEDULES))
    ap.add_argument("--microbatch", type=int, default=2)
    ap.add_argument("--seq-len", type=int, default=64)
    ap.add_argument("--m", type=int, default=8)
    ap.add_argument("--steps", type=int, default=20)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--postval", default="within_step", choices=["within_step", "sync"])
    ap.add_argument(
        "--executor",
        default="specialized",
        choices=["scan", "unroll", "specialized"],
        help="executor compilation mode (DESIGN.md Sec. 8): 'specialized' "
        "unrolls the tick stream against the static plan (fastest steps, "
        "slowest first compile -- amortized by the persistent cache); "
        "'scan' is the generic one-tick-body baseline",
    )
    ap.add_argument(
        "--no-donate",
        action="store_true",
        help="keep params/opt-state buffers undonated (doubles their peak)",
    )
    ap.add_argument(
        "--memory-budget-mb",
        type=float,
        default=None,
        help="per-device HBM budget: params + zero1 optimizer state + "
        "channel/inbox/sink buffers + schedule memory; picks the fastest "
        "schedule across all families that fits (overrides --schedule); "
        "plans are reused across processes via $REPRO_PLAN_CACHE_DIR",
    )
    args = ap.parse_args()

    # repeated runs (and the driver's retry re-jit) skip recompiles
    enable_persistent_cache()
    tcfg = TrainStepConfig(
        adamw=adamw.AdamWConfig(lr=args.lr),
        postval_mode=args.postval,
        executor_mode=args.executor,
        donate=not args.no_donate,
    )
    cfg, spec, sched, make, mesh, binding = build_everything(
        args.arch,
        args.reduced,
        args.pipe_size,
        args.tp_size,
        args.schedule,
        args.microbatch,
        args.seq_len,
        args.m,
        tcfg,
        memory_budget_bytes=(
            args.memory_budget_mb * 2**20
            if args.memory_budget_mb is not None
            else None
        ),
    )
    data = SyntheticLM(
        DataConfig(
            global_batch=spec.m * spec.microbatch,
            seq_len=spec.seq_len,
            vocab=cfg.vocab,
        )
    )
    stacked, shared = init_params(cfg, spec, sched.placement)
    opt = adamw.AdamWState(
        t=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(lambda a: jnp.zeros(a.shape, jnp.float32), stacked),
        v=jax.tree_util.tree_map(lambda a: jnp.zeros(a.shape, jnp.float32), stacked),
    )
    shared_opt = adamw.AdamWState(
        t=jnp.zeros((), jnp.int32),
        m=jax.tree_util.tree_map(lambda a: jnp.zeros(a.shape, jnp.float32), shared),
        v=jax.tree_util.tree_map(lambda a: jnp.zeros(a.shape, jnp.float32), shared),
    )

    side0 = side_from_batch(data.batch_at(0), spec, cfg=cfg)
    step = make(side0)

    def step_fn(state, batch):
        side = side_from_batch(batch, spec, cfg=cfg)
        stacked, shared, opt, shared_opt = (
            state["params"],
            state["shared"],
            state["opt"],
            state["shared_opt"],
        )
        stacked, shared, opt, shared_opt, metrics = step(
            stacked, shared, opt, shared_opt, side
        )
        return (
            dict(params=stacked, shared=shared, opt=opt, shared_opt=shared_opt),
            metrics,
        )

    driver = TrainDriver(
        DriverConfig(ckpt_dir=args.ckpt_dir, ckpt_every=max(args.steps // 2, 10)),
        step_fn,
        lambda: dict(params=stacked, shared=shared, opt=opt, shared_opt=shared_opt),
        data.batch_at,
    )
    t0 = time.time()
    _, metrics = driver.run(args.steps)
    dt = time.time() - t0
    losses = [float(m["loss"]) for _, m in metrics]
    tput = driver.throughput()
    tput_s = f" steps/s={tput:.3f}" if tput else ""
    print(f"steps={len(metrics)} wall={dt:.1f}s{tput_s} "
          f"loss[0]={losses[0]:.4f} loss[-1]={losses[-1]:.4f} "
          f"schedule={sched.name} executor={args.executor}")
    assert losses[-1] < losses[0], "loss must decrease on the synthetic stream"


if __name__ == "__main__":
    main()
