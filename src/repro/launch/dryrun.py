import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry run: lower + compile every (architecture x shape x mesh).

This proves the distribution config is coherent without real hardware: 512
placeholder CPU devices stand in for 2 pods x 256 chips; ``.lower()`` +
``.compile()`` must succeed for every cell, and the compiled artifact yields
``memory_analysis()`` (fits-per-device evidence) and ``cost_analysis()``
(FLOPs/bytes for the roofline, Sec. Roofline of EXPERIMENTS.md).

Usage:
  python -m repro.launch.dryrun --arch internlm2_1_8b --shape train_4k
  python -m repro.launch.dryrun --arch all --multi-pod --out results.json

Scan-based executors keep the HLO small; cost_analysis of a while-loop body
counts one trip, so the roofline pipeline (benchmarks/roofline.py) derives
per-tick costs separately and multiplies by the static schedule counts.
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, cells_for
from repro.core.schedules import compile_plan, zb_h1, zb_h2, zb_v
from repro.core.schedules.ir import Placement
from repro.launch.mesh import AxisBinding, make_production_mesh
from repro.launch.steps import TrainStepConfig, build_train_step, build_serve_step
from repro.models.lm import RunSpec, init_params, side_inputs
from repro.models.serve import build_serve_program


def make_run_spec(cfg, cell, mesh, binding, schedule_name):
    p, tp, dp = binding.sizes(mesh)
    gb = cell.global_batch
    per_pipe = max(1, gb // dp)
    if cell.kind == "train":
        b = 1
        m = max(per_pipe // b, 1)
    elif cell.kind == "prefill":
        b = 1
        m = max(per_pipe, 1)
    else:  # decode
        m = min(per_pipe, max(p, 16))
        b = max(1, per_pipe // m)
        m = max(1, per_pipe // b)
    n_chunks = 2 if schedule_name == "zb-v" else 1
    return RunSpec(
        p=p,
        n_chunks=n_chunks,
        microbatch=b,
        seq_len=cell.seq_len,
        m=m,
        tp_axis=binding.tp,
        tp_size=tp,
    )


def make_schedule(name, p, m):
    if name == "zb-v":
        return zb_v(p, m)
    if name == "zb-h1":
        return zb_h1(p, m)
    return zb_h2(p, m)


def abstract_side(cfg, spec, mode, dp):
    """ShapeDtypeStruct side inputs (global shapes: dp-stacked on axis 0)."""
    side = jax.eval_shape(lambda: side_inputs(cfg, spec))
    if mode == "decode":
        side = {
            "tokens": jax.ShapeDtypeStruct((spec.m, spec.microbatch, 1), jnp.int32),
            "positions": jax.ShapeDtypeStruct((spec.m, 1), jnp.int32),
        }

    def widen(sd):
        if dp > 1:
            return jax.ShapeDtypeStruct((dp * sd.shape[0],) + sd.shape[1:], sd.dtype)
        return sd

    return jax.tree_util.tree_map(widen, side)


def dryrun_cell(arch_id, shape_id, multi_pod=False, schedule="zb-h2", verbose=True):
    cfg = get_config(arch_id)
    cell = SHAPES[shape_id]
    mesh = make_production_mesh(multi_pod=multi_pod)
    binding = AxisBinding(
        pipe="data", tp="model", dp="pod" if multi_pod else None
    )
    p, tp, dp = binding.sizes(mesh)
    spec = make_run_spec(cfg, cell, mesh, binding, schedule)

    t0 = time.time()
    if cell.kind == "train":
        sched = make_schedule(schedule, p, spec.m)
        plan = compile_plan(sched)
        make, _ = build_train_step(
            cfg, spec, plan, sched.placement, mesh, binding, TrainStepConfig()
        )
        stacked, shared = jax.eval_shape(
            lambda: init_params(cfg, spec, sched.placement)
        )

        def widen_stage(sd):
            return jax.ShapeDtypeStruct((p,) + sd.shape[1:], sd.dtype)

        stacked = tuple(
            jax.tree_util.tree_map(widen_stage, sp) for sp in stacked
        )
        from repro.optim import adamw

        opt = adamw.AdamWState(
            t=jax.ShapeDtypeStruct((), jnp.int32),
            m=tuple(
                jax.tree_util.tree_map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), sp
                )
                for sp in stacked
            ),
            v=tuple(
                jax.tree_util.tree_map(
                    lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), sp
                )
                for sp in stacked
            ),
        )
        shared_opt = adamw.AdamWState(
            t=jax.ShapeDtypeStruct((), jnp.int32),
            m=jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), shared
            ),
            v=jax.tree_util.tree_map(
                lambda s: jax.ShapeDtypeStruct(s.shape, jnp.float32), shared
            ),
        )
        side = abstract_side(cfg, spec, "train", dp)
        step = make(side)
        lowered = step.lower(stacked, shared, opt, shared_opt, side)
        n_ticks = plan.n_ticks
    else:
        placement = Placement.linear(p, spec.n_chunks)
        mode = "prefill" if cell.kind == "prefill" else "decode"
        cache_len = cell.seq_len
        make, program, cache_init = build_serve_step(
            cfg, spec, placement, mesh, binding, mode, cache_len
        )
        stacked, shared = jax.eval_shape(
            lambda: init_params(cfg, spec, placement)
        )

        def widen_stage(sd):
            return jax.ShapeDtypeStruct((p,) + sd.shape[1:], sd.dtype)

        stacked = tuple(jax.tree_util.tree_map(widen_stage, sp) for sp in stacked)
        one = jax.eval_shape(lambda: cache_init(spec.microbatch, cache_len))
        caches = [
            jax.tree_util.tree_map(
                lambda sd: jax.ShapeDtypeStruct(
                    (p, spec.m) + sd.shape, sd.dtype
                ),
                one,
            )
            for _ in range(spec.n_chunks)
        ]
        side = abstract_side(cfg, spec, mode, dp)
        step = make(stacked, shared, side, caches)
        lowered = step.lower(stacked, shared, side, caches)
        from repro.core.infer_executor import compile_infer_plan

        n_ticks = compile_infer_plan(placement, spec.m).n_ticks

    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    cost = compiled.cost_analysis()
    if isinstance(cost, (list, tuple)):  # one dict per device program
        cost = cost[0] if cost else {}
    # Calibrate the analytic byte model against the compiled artifact: the
    # part of the XLA temp footprint the schedule-buffer model cannot see
    # becomes a per-config fudge term the HBM planner charges against the
    # budget (ActivationByteModel.calibrate_from_dryrun, DESIGN.md Sec. 6).
    xla_temp = modeled_schedule = None
    if cell.kind == "train":
        from repro.core.memory import ActivationByteModel

        byte_model = ActivationByteModel.from_config(
            cfg, spec.microbatch, spec.seq_len, p,
            n_chunks=spec.n_chunks, tp_size=tp,
        )
        modeled_schedule = byte_model.schedule_bytes(sched)[2]
        calibrated = byte_model.calibrate_from_dryrun(mem, sched)
        xla_temp = calibrated.xla_temp_bytes
    result = {
        "arch": arch_id,
        "shape": shape_id,
        "mesh": "2x16x16" if multi_pod else "16x16",
        "schedule": schedule if cell.kind == "train" else f"fill-drain-{cell.kind}",
        "p": p,
        "tp": tp,
        "dp": dp,
        "m": spec.m,
        "microbatch": spec.microbatch,
        "n_ticks": int(n_ticks),
        "lower_s": round(t_lower, 1),
        "compile_s": round(t_compile, 1),
        "bytes_per_device": getattr(mem, "temp_size_in_bytes", None),
        "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
        "output_bytes": getattr(mem, "output_size_in_bytes", None),
        "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
        "flops": cost.get("flops") if cost else None,
        "bytes_accessed": cost.get("bytes accessed") if cost else None,
        # per-config planner calibration (train cells): feed xla_temp_bytes
        # to repro.core.planner.plan(..., xla_temp_bytes=...)
        "modeled_schedule_bytes": modeled_schedule,
        "xla_temp_bytes": xla_temp,
    }
    if verbose:
        print(json.dumps(result))
        sys.stdout.flush()
    return result, lowered, compiled


def write_calibration_table(results, path):
    """Fold train-cell results into the checked-in planner calibration.

    ``configs/xla_temp_calibration.json`` maps arch name -> the compiled
    cell's XLA temp in excess of the modeled schedule bytes, plus the
    calibration shape (per-device tokens, tp, p, schedule) so
    ``repro.core.memory.default_xla_temp_bytes`` can scale it to a planned
    run shape.  Existing entries for other archs are preserved, so the
    grid can be (re)run arch-by-arch.
    """
    try:
        with open(path) as f:
            table = json.load(f)
    except (OSError, ValueError):
        table = {}
    from repro.core.memory import ActivationByteModel

    for r in results:
        if r.get("xla_temp_bytes") is None:
            continue
        cfg = get_config(r["arch"])
        cell = SHAPES[r["shape"]]
        # the calibration cell's modeled M_B unit: the scale reference for
        # re-pricing the temp at other run shapes / reduced() variants
        m_b_cal = ActivationByteModel.from_config(
            cfg, r["microbatch"], cell.seq_len, r["p"],
            n_chunks=2 if r["schedule"] == "zb-v" else 1, tp_size=r["tp"],
        ).m_b_bytes
        table[cfg.name] = {
            "xla_temp_bytes": r["xla_temp_bytes"],
            "modeled_schedule_bytes": r.get("modeled_schedule_bytes"),
            "m_b_bytes": m_b_cal,
            "tokens": r["microbatch"] * cell.seq_len,
            "tp": r["tp"],
            "p": r["p"],
            "schedule": r["schedule"],
            "shape": r["shape"],
            "arch_id": r["arch"],
        }
    with open(path, "w") as f:
        json.dump(table, f, indent=1, sort_keys=True)
    return table


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--schedule", default="zb-h2", choices=["zb-h1", "zb-h2", "zb-v"])
    ap.add_argument("--out", default=None)
    ap.add_argument(
        "--calibration-out",
        default=None,
        help="merge train-cell xla_temp_bytes into this planner calibration "
        "table (configs/xla_temp_calibration.json)",
    )
    args = ap.parse_args()

    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    results = []
    for arch in archs:
        for sid, cell, skip in cells_for(arch):
            if args.shape != "all" and sid != args.shape:
                continue
            if skip:
                rec = {"arch": arch, "shape": sid, "skipped": skip}
                print(json.dumps(rec))
                results.append(rec)
                continue
            try:
                rec, _, _ = dryrun_cell(
                    arch, sid, multi_pod=args.multi_pod, schedule=args.schedule
                )
                results.append(rec)
            except Exception as e:
                traceback.print_exc()
                results.append(
                    {"arch": arch, "shape": sid, "error": f"{type(e).__name__}: {e}"}
                )
    if args.out:
        with open(args.out, "w") as f:
            json.dump(results, f, indent=1)
    if args.calibration_out:
        write_calibration_table(results, args.calibration_out)
    bad = [r for r in results if "error" in r]
    print(f"\n{len(results) - len(bad)}/{len(results)} cells OK")
    sys.exit(1 if bad else 0)


if __name__ == "__main__":
    main()
