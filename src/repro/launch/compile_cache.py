"""Persistent XLA compilation cache (repo-wide switch).

The specialized executor trades compile time for step time (DESIGN.md
Sec. 8); enabling JAX's persistent compilation cache makes that trade
one-off per (program, plan, mode): repeated runs, the benchmark harness,
and CI re-runs skip recompiles entirely.

Controlled by ``$REPRO_JAX_CACHE_DIR``:
  * unset        -> ``~/.cache/repro-zb/jax`` (created on demand),
  * a path       -> that directory,
  * ``off``/``0``/empty -> disabled.
"""

from __future__ import annotations

import os
from typing import Optional

__all__ = ["enable_persistent_cache", "cache_dir_from_env"]

_ENV = "REPRO_JAX_CACHE_DIR"
_DEFAULT = os.path.join("~", ".cache", "repro-zb", "jax")


def cache_dir_from_env() -> Optional[str]:
    raw = os.environ.get(_ENV)
    if raw is None:
        raw = _DEFAULT
    if raw.strip().lower() in ("", "off", "0", "none"):
        return None
    return os.path.expanduser(raw)


def enable_persistent_cache(cache_dir: Optional[str] = None) -> Optional[str]:
    """Point JAX's persistent compilation cache at a durable directory.

    Idempotent; returns the directory in use (``None`` when disabled).
    Thresholds are zeroed so even small tick programs are cached -- the
    specialized executor's value is precisely that its *large* trace cost
    is paid once.
    """
    import jax

    if cache_dir is None:
        cache_dir = cache_dir_from_env()
    if cache_dir is None:
        return None
    os.makedirs(cache_dir, exist_ok=True)
    jax.config.update("jax_compilation_cache_dir", cache_dir)
    jax.config.update("jax_persistent_cache_min_entry_size_bytes", -1)
    jax.config.update("jax_persistent_cache_min_compile_time_secs", 0.0)
    return cache_dir
