"""Production mesh + logical axis binding.

Physical mesh shapes are fixed by the deployment target: (16, 16) =
("data", "model") per pod; (2, 16, 16) = ("pod", "data", "model") for two
pods.  The framework binds *logical* roles onto physical axes:

  * pp="data"  -- 16 pipeline stages.  PP tolerates the weakest links
    (cross-host / cross-pod), which is the paper's motivation for improving
    it; the per-tick traffic is one (b, s, h) activation per channel.
  * tp="model" -- 16-way Megatron tensor parallelism on the fastest links.
  * dp="pod"   -- data parallelism across pods; the gradient all-reduce
    crosses pods once per step and overlaps with the W tail (paper App. A).

``make_production_mesh`` is a function (not a module constant) so importing
this module never touches jax device state.
"""

from __future__ import annotations

import dataclasses
from typing import Optional, Tuple

import jax

__all__ = ["make_production_mesh", "AxisBinding", "PRODUCTION_BINDING"]


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


@dataclasses.dataclass(frozen=True)
class AxisBinding:
    pipe: str = "data"
    tp: Optional[str] = "model"
    dp: Optional[str] = None  # "pod" on the multi-pod mesh

    def sizes(self, mesh) -> Tuple[int, int, int]:
        ax = dict(zip(mesh.axis_names, mesh.devices.shape))
        return (
            ax[self.pipe],
            ax[self.tp] if self.tp else 1,
            ax[self.dp] if self.dp else 1,
        )


PRODUCTION_BINDING = AxisBinding(pipe="data", tp="model", dp=None)
