from .driver import (
    DriverConfig,
    TrainDriver,
    rebalance_layers,
    replan_for_stragglers,
)

__all__ = [
    "DriverConfig",
    "TrainDriver",
    "rebalance_layers",
    "replan_for_stragglers",
]
