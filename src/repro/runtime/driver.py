"""Fault-tolerant training driver.

Production loop responsibilities implemented here:
  * checkpoint every N steps (atomic, sharded), restart-from-latest;
  * step retry on transient failure (the paper-level analogue of a preempted
    pod: re-build the jitted step and replay from the last checkpoint --
    deterministic data makes replay exact);
  * straggler mitigation: accept a per-stage time profile (from the runtime's
    monitor) and *re-search the schedule* for the imbalanced profile -- the
    ZB auto-scheduler is the mitigation mechanism (DESIGN.md Sec. 2);
  * elastic scaling: re-plan schedule + re-shard checkpoint for a new p
    (checkpoint/store.reshard_stages).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint import store
from ..core.schedules import search
from ..core.simulator import TimeModel

log = logging.getLogger("repro.driver")

__all__ = [
    "DriverConfig",
    "TrainDriver",
    "replan_for_stragglers",
    "replan_under_budget",
    "rebalance_layers",
]


@dataclasses.dataclass
class DriverConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_retries: int = 3
    keep_last: int = 3


def replan_for_stragglers(
    p: int,
    m: int,
    base_times: TimeModel,
    stage_scale,
    m_limit: float,
):
    """Re-search the ZB schedule for an observed per-stage slowdown profile.

    Returns (schedule, predicted_cost, baseline_cost): the baseline is the
    balanced-profile schedule evaluated under the *observed* profile.
    """
    from ..core.simulator import simulate

    observed = dataclasses.replace(base_times, stage_scale=tuple(stage_scale))
    balanced = search(p, m, base_times, m_limit=m_limit)
    base_cost = simulate(balanced.schedule, observed).cost
    replanned = search(p, m, observed, m_limit=m_limit)
    return replanned.schedule, replanned.cost, base_cost


def replan_under_budget(
    cfg,
    p: int,
    m: int,
    microbatch: int,
    seq_len: int,
    budget_bytes: float,
    base_times: Optional[TimeModel] = None,
    stage_scale=None,
    tp_size: int = 1,
    program_factory=None,
):
    """Re-plan the schedule when the per-device memory budget changes.

    Runtime counterpart of launch-time planning (DESIGN.md Sec. 5): after an
    elastic reshard, a sequence-length bump, or a co-tenant claiming device
    memory, the driver re-runs the byte-level planner -- optionally under the
    monitor's observed straggler profile -- and returns
    (schedule, PlannerDecision).  Raises RuntimeError with the planner's
    report when nothing fits, so the caller can shrink the microbatch or
    spill instead of OOMing mid-run.

    When ``program_factory(n_chunks) -> (program, stage_params, shared,
    side)`` is supplied (pytrees may be ``ShapeDtypeStruct``; nothing is
    computed), the chosen plan is additionally validated against *measured*
    executor buffer bytes (:func:`repro.core.memory.measured_timeline`) --
    the budget is then enforced on real buffers, not just the analytic
    model.
    """
    from ..core.memory import MemoryBudgetPlanner, measured_timeline

    times = base_times or TimeModel.unit()
    if stage_scale is not None:
        times = dataclasses.replace(times, stage_scale=tuple(stage_scale))
    planner = MemoryBudgetPlanner(
        cfg, p=p, m=m, microbatch=microbatch, seq_len=seq_len,
        times=times, tp_size=tp_size,
    )
    decision = planner.plan(budget_bytes)
    if not decision.feasible:
        raise RuntimeError(f"no schedule fits the budget: {decision.summary()}")
    if program_factory is not None:
        from ..core.executor import PipelineExecutor
        from ..core.schedules import compile_plan

        chosen = decision.chosen.schedule
        program, stage_params, shared, side = program_factory(chosen.n_chunks)
        exe = PipelineExecutor(program, compile_plan(chosen))
        mt = measured_timeline(exe, stage_params, shared, side)
        if mt.alloc_total > budget_bytes:
            raise RuntimeError(
                "budget infeasible on measured executor buffers: "
                f"{decision.chosen.name} allocates {mt.alloc_total/2**20:.0f} "
                f"MiB > budget {budget_bytes/2**20:.0f} MiB "
                f"(act {mt.alloc_act/2**20:.0f}, wctx {mt.alloc_wctx/2**20:.0f},"
                f" inbox {mt.alloc_inbox/2**20:.0f} MiB)"
            )
        log.info(
            "measured executor bytes for %s: %.0f MiB (act %.0f, wctx %.0f)",
            decision.chosen.name, mt.alloc_total / 2**20,
            mt.alloc_act / 2**20, mt.alloc_wctx / 2**20,
        )
    log.info("replanned under budget: %s", decision.summary())
    return decision.chosen.schedule, decision


def rebalance_layers(
    p: int,
    m: int,
    base_times: TimeModel,
    stage_scale,
    layers_per_stage: int,
    m_limit: float,
):
    """Straggler mitigation for a uniformly-slow stage: move layers off it.

    Op re-ordering alone cannot shrink the max-span of a stage whose every
    pass is slower; re-partitioning layers can.  Greedy: move one layer from
    the most-loaded stage (observed scale x layer count) to the least-loaded
    neighbourhood while the simulated ZB cost improves.  Returns
    (layer_counts, schedule, new_cost, old_cost) -- the elastic-reshard
    machinery (checkpoint.store.reshard_stages) then moves the weights.
    """
    from ..core.schedules import zb_h2
    from ..core.simulator import simulate

    g0 = layers_per_stage
    layers = [g0] * p

    def cost(lay):
        scale = tuple(stage_scale[s] * lay[s] / g0 for s in range(p))
        tm = dataclasses.replace(base_times, stage_scale=scale)
        return simulate(zb_h2(p, m), tm).cost

    old_cost = cost(layers)
    best = old_cost
    for _ in range(p * g0):
        load = [stage_scale[s] * layers[s] for s in range(p)]
        src = int(np.argmax(load))
        dst = int(np.argmin(load))
        if layers[src] <= 1 or src == dst:
            break
        cand = list(layers)
        cand[src] -= 1
        cand[dst] += 1
        c = cost(cand)
        if c >= best - 1e-9:
            break
        layers, best = cand, c
    scale = tuple(stage_scale[s] * layers[s] / g0 for s in range(p))
    tm = dataclasses.replace(base_times, stage_scale=scale)
    final = search(p, m, tm, m_limit=m_limit)
    return layers, final.schedule, min(final.cost, best), old_cost


class TrainDriver:
    """step_fn(state, batch) -> (state, metrics); state is a dict pytree."""

    def __init__(
        self,
        cfg: DriverConfig,
        step_fn: Callable,
        init_state: Callable[[], Dict[str, Any]],
        data_at: Callable[[int], Any],
    ):
        self.cfg = cfg
        self.step_fn = step_fn
        self.init_state = init_state
        self.data_at = data_at

    def _restore_or_init(self):
        last = store.latest_step(self.cfg.ckpt_dir)
        state = self.init_state()
        if last is None:
            return state, 0
        state, manifest = store.restore(self.cfg.ckpt_dir, last, state)
        log.info("restored checkpoint step %d", last)
        return state, last

    def run(self, n_steps: int, fail_hook: Optional[Callable[[int], None]] = None):
        """fail_hook(step) may raise to simulate a node failure (tests)."""
        state, start = self._restore_or_init()
        metrics_log = []
        step = start
        retries = 0
        while step < n_steps:
            try:
                if fail_hook is not None:
                    fail_hook(step)
                batch = self.data_at(step)
                state, metrics = self.step_fn(state, batch)
                metrics = jax.tree_util.tree_map(np.asarray, metrics)
                metrics_log.append((step, metrics))
                step += 1
                retries = 0
                if step % self.cfg.ckpt_every == 0 or step == n_steps:
                    store.save(self.cfg.ckpt_dir, step, state)
                    self._gc()
            except Exception:
                retries += 1
                if retries > self.cfg.max_retries:
                    raise
                log.exception("step %d failed; retry %d", step, retries)
                state, step = self._restore_or_init()
        return state, metrics_log

    def _gc(self):
        import os
        import shutil

        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.cfg.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.cfg.keep_last]:
            shutil.rmtree(
                os.path.join(self.cfg.ckpt_dir, f"step_{s:08d}"), ignore_errors=True
            )
