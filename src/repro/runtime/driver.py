"""Fault-tolerant training driver.

Production loop responsibilities implemented here:
  * checkpoint every N steps (atomic, sharded), restart-from-latest;
  * step retry on transient failure (the paper-level analogue of a preempted
    pod: re-build the jitted step and replay from the last checkpoint --
    deterministic data makes replay exact);
  * straggler mitigation: accept a per-stage time profile (from the runtime's
    monitor) and *re-search the schedule* for the imbalanced profile -- the
    ZB auto-scheduler is the mitigation mechanism (DESIGN.md Sec. 2);
  * elastic scaling: re-plan schedule + re-shard checkpoint for a new p
    (checkpoint/store.reshard_stages).
"""

from __future__ import annotations

import dataclasses
import logging
import time
from typing import Any, Callable, Dict, Optional

import jax
import numpy as np

from ..checkpoint import store
from ..core.schedules import search
from ..core.simulator import TimeModel

log = logging.getLogger("repro.driver")

__all__ = [
    "DriverConfig",
    "TrainDriver",
    "replan_for_stragglers",
    "replan_under_budget",
    "rebalance_layers",
]


@dataclasses.dataclass
class DriverConfig:
    ckpt_dir: str
    ckpt_every: int = 50
    max_retries: int = 3
    keep_last: int = 3


def replan_for_stragglers(
    p: int,
    m: int,
    base_times: TimeModel,
    stage_scale,
    m_limit: float,
):
    """Re-plan the schedule for an observed per-stage slowdown profile.

    Delegates to the unified planning layer's family search
    (:func:`repro.core.planner.fastest_under_profile`): every schedule
    family -- the Sec.-3.1 greedy grid, the handcrafted portfolio, the
    v_flex portfolio, ZB-V/V-Half/V-Min -- is re-simulated under the
    observed profile and the cheapest one under the unit memory limit
    wins.  Returns (schedule, predicted_cost, baseline_cost): the baseline
    is the balanced-profile choice evaluated under the *observed* profile,
    and the balanced choice itself stays in the candidate pool, so the
    replanned cost never exceeds the baseline.
    """
    from ..core.planner import fastest_under_profile
    from ..core.simulator import simulate

    observed = dataclasses.replace(base_times, stage_scale=tuple(stage_scale))
    balanced, _ = fastest_under_profile(p, m, base_times, m_limit)
    base_cost = simulate(balanced, observed).cost
    replanned, cost = fastest_under_profile(p, m, observed, m_limit)
    if base_cost < cost:  # the balanced pick is itself a valid candidate
        replanned, cost = balanced, base_cost
    return replanned, cost, base_cost


def replan_under_budget(
    cfg,
    p: int,
    m: int,
    microbatch: int,
    seq_len: int,
    budget_bytes: float,
    base_times: Optional[TimeModel] = None,
    stage_scale=None,
    tp_size: int = 1,
    dp_size: int = 1,
    program_factory=None,
    xla_temp_bytes: Optional[float] = None,
):
    """Re-plan the schedule when the per-device HBM budget changes.
    ``xla_temp_bytes=None`` (default) charges the checked-in per-config
    dryrun calibration, like launch-time planning.

    Runtime counterpart of launch-time planning (DESIGN.md Sec. 6): after an
    elastic reshard, a sequence-length bump, or a co-tenant claiming device
    memory, the driver re-runs the unified planner
    (:func:`repro.core.planner.plan`) -- optionally under the monitor's
    observed straggler profile -- and returns (schedule,
    :class:`~repro.core.planner.PlanReport`).  The budget is a *total*
    per-device HBM budget: parameters, ZeRO-1-sharded optimizer state,
    channel/inbox/sink buffers and the XLA-temp fudge are charged on top of
    the schedule's activation/W-context bytes.  Raises RuntimeError with
    the planner's itemized report (naming the binding term) when nothing
    fits, so the caller can shrink the microbatch or spill instead of
    OOMing mid-run.

    When ``program_factory(n_chunks) -> (program, stage_params, shared,
    side)`` is supplied (pytrees may be ``ShapeDtypeStruct``; nothing is
    computed), the planner switches to *measured* fidelity: every
    candidate's act/wctx/inbox/sink bytes come from the tick executor's
    real buffer allocation (``PipelineExecutor.buffer_bytes``), so the
    budget is enforced on real buffers, not just the analytic model.
    """
    from ..core.planner import HBMPlanner, plan as plan_hbm

    times = base_times or TimeModel.unit()
    if stage_scale is not None:
        times = dataclasses.replace(times, stage_scale=tuple(stage_scale))
    measured = program_factory is not None
    if measured:
        # a factory is process-local state; plan without the disk cache
        planner = HBMPlanner(
            cfg, p=p, m=m, microbatch=microbatch, seq_len=seq_len,
            times=times, tp_size=tp_size, dp_size=dp_size,
            measured=True, program_factory=program_factory,
            xla_temp_bytes=xla_temp_bytes,
        )
        report = planner.plan(budget_bytes)
    else:
        report = plan_hbm(
            cfg, p, m, times, budget_bytes,
            microbatch=microbatch, seq_len=seq_len,
            tp_size=tp_size, dp_size=dp_size,
            xla_temp_bytes=xla_temp_bytes,
        )
    if not report.feasible:
        fidelity = "measured executor buffers" if measured else "the byte model"
        raise RuntimeError(
            f"no schedule fits the per-device HBM budget (on {fidelity}): "
            f"{report.infeasibility_report()}"
        )
    if measured:
        bd = report.chosen.breakdown
        log.info(
            "measured executor bytes for %s: %.0f MiB (act %.0f, wctx %.0f, "
            "inbox %.0f)", report.chosen.name, bd.schedule_bytes / 2**20,
            bd.act / 2**20, bd.wctx / 2**20, bd.inbox / 2**20,
        )
    log.info("replanned under budget: %s", report.summary())
    return report.chosen.schedule, report


def rebalance_layers(
    p: int,
    m: int,
    base_times: TimeModel,
    stage_scale,
    layers_per_stage: int,
    m_limit: float,
):
    """Straggler mitigation for a uniformly-slow stage: move layers off it.

    Op re-ordering alone cannot shrink the max-span of a stage whose every
    pass is slower; re-partitioning layers can.  Greedy: move one layer from
    the most-loaded stage (observed scale x layer count) to the least-loaded
    neighbourhood while the simulated ZB cost improves.  Returns
    (layer_counts, schedule, new_cost, old_cost) -- the elastic-reshard
    machinery (checkpoint.store.reshard_stages) then moves the weights.
    """
    from ..core.schedules import zb_h2
    from ..core.simulator import simulate

    g0 = layers_per_stage
    layers = [g0] * p

    def cost(lay):
        scale = tuple(stage_scale[s] * lay[s] / g0 for s in range(p))
        tm = dataclasses.replace(base_times, stage_scale=scale)
        return simulate(zb_h2(p, m), tm).cost

    old_cost = cost(layers)
    best = old_cost
    for _ in range(p * g0):
        load = [stage_scale[s] * layers[s] for s in range(p)]
        src = int(np.argmax(load))
        dst = int(np.argmin(load))
        if layers[src] <= 1 or src == dst:
            break
        cand = list(layers)
        cand[src] -= 1
        cand[dst] += 1
        c = cost(cand)
        if c >= best - 1e-9:
            break
        layers, best = cand, c
    scale = tuple(stage_scale[s] * layers[s] / g0 for s in range(p))
    tm = dataclasses.replace(base_times, stage_scale=scale)
    final = search(p, m, tm, m_limit=m_limit)
    return layers, final.schedule, min(final.cost, best), old_cost


class TrainDriver:
    """step_fn(state, batch) -> (state, metrics); state is a dict pytree."""

    def __init__(
        self,
        cfg: DriverConfig,
        step_fn: Callable,
        init_state: Callable[[], Dict[str, Any]],
        data_at: Callable[[int], Any],
    ):
        self.cfg = cfg
        self.step_fn = step_fn
        self.init_state = init_state
        self.data_at = data_at
        # per-step wall seconds of the last run() (includes the first,
        # compile-bearing step); feeds the perf trajectory -- see
        # benchmarks/throughput.py
        self.step_times: list = []

    def throughput(self, skip: int = 1) -> Optional[float]:
        """Steady-state steps/s of the last run, skipping warmup steps."""
        times = self.step_times[skip:]
        if not times:
            return None
        return len(times) / sum(times)

    def _restore_or_init(self):
        last = store.latest_step(self.cfg.ckpt_dir)
        state = self.init_state()
        if last is None:
            return state, 0
        state, manifest = store.restore(self.cfg.ckpt_dir, last, state)
        log.info("restored checkpoint step %d", last)
        return state, last

    def run(self, n_steps: int, fail_hook: Optional[Callable[[int], None]] = None):
        """fail_hook(step) may raise to simulate a node failure (tests)."""
        state, start = self._restore_or_init()
        metrics_log = []
        self.step_times = []
        step = start
        retries = 0
        while step < n_steps:
            try:
                if fail_hook is not None:
                    fail_hook(step)
                batch = self.data_at(step)
                t0 = time.perf_counter()
                state, metrics = self.step_fn(state, batch)
                metrics = jax.tree_util.tree_map(np.asarray, metrics)
                self.step_times.append(time.perf_counter() - t0)
                metrics_log.append((step, metrics))
                step += 1
                retries = 0
                if step % self.cfg.ckpt_every == 0 or step == n_steps:
                    store.save(self.cfg.ckpt_dir, step, state)
                    self._gc()
            except Exception:
                retries += 1
                if retries > self.cfg.max_retries:
                    raise
                log.exception("step %d failed; retry %d", step, retries)
                state, step = self._restore_or_init()
        return state, metrics_log

    def _gc(self):
        import os
        import shutil

        steps = sorted(
            int(d.split("_")[1])
            for d in os.listdir(self.cfg.ckpt_dir)
            if d.startswith("step_") and not d.endswith(".tmp")
        )
        for s in steps[: -self.cfg.keep_last]:
            shutil.rmtree(
                os.path.join(self.cfg.ckpt_dir, f"step_{s:08d}"), ignore_errors=True
            )
