"""Deterministic token data pipeline.

Production shape: a sharded, host-prefetching iterator over fixed-length
token sequences.  Sources: synthetic (seeded per (step, dp_rank) -- fully
deterministic and restart-reproducible, which the fault-tolerance tests rely
on) or a memory-mapped token file.  Each batch is
{tokens, labels: (global_batch, seq)} with labels = next-token shift.
"""

from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Iterator, Optional

import jax
import numpy as np

__all__ = ["DataConfig", "SyntheticLM", "TokenFileLM", "prefetch"]


@dataclasses.dataclass(frozen=True)
class DataConfig:
    global_batch: int
    seq_len: int
    vocab: int
    seed: int = 0


class SyntheticLM:
    """Seeded synthetic LM stream: batch at step k is a pure function of
    (seed, k) -- restartable from any step without replay."""

    def __init__(self, cfg: DataConfig):
        self.cfg = cfg

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        rng = np.random.default_rng(np.random.SeedSequence([cfg.seed, step]))
        # noisy successor chain: strongly learnable bigram structure so short
        # smoke runs show a clear loss decrease
        n, s = cfg.global_batch, cfg.seq_len + 1
        toks = np.empty((n, s), np.int64)
        toks[:, 0] = rng.integers(0, cfg.vocab, size=n)
        noise = rng.random((n, s - 1)) < 0.15
        jumps = rng.integers(0, cfg.vocab, size=(n, s - 1))
        for t in range(1, s):
            nxt = (toks[:, t - 1] + 1) % cfg.vocab
            toks[:, t] = np.where(noise[:, t - 1], jumps[:, t - 1], nxt)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self) -> Iterator[dict]:
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


class TokenFileLM:
    """Memory-mapped flat token file (np.int32), strided into sequences."""

    def __init__(self, path: str, cfg: DataConfig):
        self.cfg = cfg
        self.tokens = np.memmap(path, dtype=np.int32, mode="r")
        self.n_seq = (len(self.tokens) - 1) // cfg.seq_len

    def batch_at(self, step: int) -> dict:
        cfg = self.cfg
        idx = (
            np.arange(cfg.global_batch) + step * cfg.global_batch
        ) % self.n_seq
        starts = idx * cfg.seq_len
        toks = np.stack(
            [self.tokens[s : s + cfg.seq_len + 1] for s in starts]
        )
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    def __iter__(self):
        step = 0
        while True:
            yield self.batch_at(step)
            step += 1


def prefetch(it: Iterator, depth: int = 2) -> Iterator:
    """Host-side prefetch thread (overlaps batch prep with device steps)."""
    q: "queue.Queue" = queue.Queue(maxsize=depth)
    stop = object()

    def worker():
        try:
            for item in it:
                q.put(item)
        finally:
            q.put(stop)

    t = threading.Thread(target=worker, daemon=True)
    t.start()
    while True:
        item = q.get()
        if item is stop:
            return
        yield item
