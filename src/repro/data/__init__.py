from .pipeline import DataConfig, SyntheticLM, TokenFileLM, prefetch

__all__ = ["DataConfig", "SyntheticLM", "TokenFileLM", "prefetch"]
