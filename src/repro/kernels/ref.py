"""Pure-jnp oracles for the Pallas kernels (tests assert_allclose vs these)."""

import jax
import jax.numpy as jnp


def wgrad_accum_ref(a, g, acc):
    """out = acc + a^T @ g, fp32 accumulation, cast to acc dtype."""
    d = jnp.float32
    return (
        acc.astype(d)
        + jax.lax.dot_general(
            a,
            g,
            (((0,), (0,)), ((), ())),
            preferred_element_type=d,
        )
    ).astype(acc.dtype)


def rmsnorm_ref(x, g, eps=1e-6):
    x32 = x.astype(jnp.float32)
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    y = x32 * jax.lax.rsqrt(var + eps)
    return (y * (1.0 + g.astype(jnp.float32))).astype(x.dtype)
