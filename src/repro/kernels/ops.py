"""Jit'd public wrappers for the Pallas kernels.

``use_pallas`` selects the kernel path (TPU target; interpret=True on CPU);
both compose with the F/B/W machinery: ``wgrad_accum`` *is* a W-pass op (no
vjp needed), ``rmsnorm`` gets a custom_vjp whose backward is the jnp oracle's
(the forward saves only x and g -- inv-rms is recomputed in VMEM, cheaper
than an extra HBM tensor).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from .ref import rmsnorm_ref, wgrad_accum_ref
from .rmsnorm import rmsnorm_fused
from .wgrad_accum import wgrad_accum as _wgrad_pallas

__all__ = ["wgrad_accum", "rmsnorm"]


def wgrad_accum(a, g, acc, *, use_pallas=False, interpret=True, **tiles):
    if use_pallas:
        return _wgrad_pallas(a, g, acc, interpret=interpret, **tiles)
    return wgrad_accum_ref(a, g, acc)


@functools.partial(jax.custom_vjp, nondiff_argnums=(2, 3))
def rmsnorm(x, g, use_pallas=False, interpret=True):
    if use_pallas:
        return rmsnorm_fused(x, g, interpret=interpret)
    return rmsnorm_ref(x, g)


def _rms_fwd(x, g, use_pallas, interpret):
    return rmsnorm(x, g, use_pallas, interpret), (x, g)


def _rms_bwd(use_pallas, interpret, res, dy):
    x, g = res
    x32 = x.astype(jnp.float32)
    dy32 = dy.astype(jnp.float32)
    h = x.shape[-1]
    var = jnp.mean(x32 * x32, axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + 1e-6)
    xhat = x32 * inv
    dg = jnp.sum(dy32 * xhat, axis=tuple(range(x.ndim - 1)))
    dxhat = dy32 * (1.0 + g.astype(jnp.float32))
    dx = inv * (dxhat - xhat * jnp.mean(dxhat * xhat, axis=-1, keepdims=True))
    return dx.astype(x.dtype), dg.astype(g.dtype)


rmsnorm.defvjp(_rms_fwd, _rms_bwd)
