"""Fused weight-gradient accumulation kernel: ``out = acc + a^T @ g``.

The W pass the zero-bubble schedules expose is a long tail of
``dW += activation^T @ output_grad`` updates (paper App. A reorders exactly
these for DP overlap).  XLA emits them as matmul + separate add, costing an
extra full read+write of ``acc`` over HBM; this kernel fuses the accumulate
into the matmul epilogue, saving 2*H*F*4 bytes of HBM traffic per call --
the W pass is *memory-bound* at microbatch b=1 (see EXPERIMENTS.md Perf).

TPU mapping: grid (H/bh, F/bf, N/bn) with the contraction (N) innermost so
each output tile is revisited with its fp32 partial sums held in a VMEM
scratch accumulator; ``acc`` is added on the first visit and the tile is
written back once on the last.  Tile defaults are MXU-aligned (128x128) with
bn=512 for >= 4 systolic passes per tile visit; VMEM working set =
bn*(bh+bf)*2B + bh*bf*4B = 192 KiB at defaults, well under the ~16 MiB
budget, leaving headroom for the pipelined next-block prefetch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

__all__ = ["wgrad_accum"]


def _kernel(a_ref, g_ref, acc_ref, out_ref, scratch):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        scratch[...] = acc_ref[...].astype(jnp.float32)

    scratch[...] += jax.lax.dot_general(
        a_ref[...],
        g_ref[...],
        (((0,), (0,)), ((), ())),  # contract over bn: a^T @ g
        preferred_element_type=jnp.float32,
    )

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        out_ref[...] = scratch[...].astype(out_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bh", "bf", "bn", "interpret"))
def wgrad_accum(a, g, acc, *, bh=128, bf=128, bn=512, interpret=False):
    """a: (N, H); g: (N, F); acc: (H, F) -> acc + a^T @ g  (acc dtype)."""
    n, h = a.shape
    n2, f = g.shape
    assert n == n2, (a.shape, g.shape)
    bh, bf, bn = min(bh, h), min(bf, f), min(bn, n)
    assert h % bh == 0 and f % bf == 0 and n % bn == 0, (
        f"shapes ({n},{h})x({n},{f}) must tile by (bn={bn},bh={bh},bf={bf})"
    )
    return pl.pallas_call(
        _kernel,
        grid=(h // bh, f // bf, n // bn),
        in_specs=[
            pl.BlockSpec((bn, bh), lambda i, j, k: (k, i)),
            pl.BlockSpec((bn, bf), lambda i, j, k: (k, j)),
            pl.BlockSpec((bh, bf), lambda i, j, k: (i, j)),
        ],
        out_specs=pl.BlockSpec((bh, bf), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((h, f), acc.dtype),
        scratch_shapes=[pltpu.VMEM((bh, bf), jnp.float32)],
        interpret=interpret,
    )(a, g, acc)
