"""Fused RMSNorm kernel: single HBM pass, fp32 math in VMEM.

Every dense/MoE architecture here hits RMSNorm 2x per block; unfused XLA on
small rows pays separate reduce + scale passes.  Grid over row blocks; each
block computes mean-square and normalizes in registers/VMEM.  Row block br
is chosen so br * H * 2B stays well inside VMEM (default 256 x 8192 bf16 =
4 MiB).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

__all__ = ["rmsnorm_fused"]


def _kernel(x_ref, g_ref, o_ref, *, eps):
    x = x_ref[...].astype(jnp.float32)  # (br, H)
    var = jnp.mean(x * x, axis=-1, keepdims=True)
    y = x * jax.lax.rsqrt(var + eps)
    o_ref[...] = (y * (1.0 + g_ref[...].astype(jnp.float32))).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("br", "eps", "interpret"))
def rmsnorm_fused(x, g, *, br=256, eps=1e-6, interpret=False):
    """x: (N, H); g: (H,) -> rmsnorm(x) * (1 + g), single pass."""
    n, h = x.shape
    br = min(br, n)
    assert n % br == 0, f"rows {n} must tile by {br}"
    kernel = functools.partial(_kernel, eps=eps)
    return pl.pallas_call(
        kernel,
        grid=(n // br,),
        in_specs=[
            pl.BlockSpec((br, h), lambda i: (i, 0)),
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((br, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, h), x.dtype),
        interpret=interpret,
    )(x, g)
