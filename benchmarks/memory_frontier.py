"""Memory-vs-throughput frontier sweep (controllable-memory subsystem).

For each config, build a :class:`MemoryBudgetPlanner` and sweep an ascending
per-device byte budget from just below the cheapest plan to comfortably above
the hungriest one.  At every point record the planner's decision; the
resulting cost-vs-budget curve must be monotone (more memory never yields a
slower plan -- guaranteed by the planner's cumulative candidate pool and
asserted here).

Writes ``BENCH_memory_frontier.json``:

  {config: {"m_b_bytes": ..., "points": [
      {"budget_bytes", "feasible", "schedule", "cost", "bubble_rate",
       "total_bytes", "min_required_bytes"}, ...]}}

Usage: python benchmarks/memory_frontier.py [--configs a,b,c] [--points N]
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.configs import get_config
from repro.core.memory import MemoryBudgetPlanner
from repro.core.simulator import TimeModel

DEFAULT_CONFIGS = ["gpt3_1_5b", "gpt3_6_2b", "gemma2_2b"]
OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_memory_frontier.json")


def sweep(arch: str, p: int, m: int, microbatch: int, seq_len: int, n_points: int):
    cfg = get_config(arch)
    planner = MemoryBudgetPlanner(
        cfg, p=p, m=m, microbatch=microbatch, seq_len=seq_len,
        times=TimeModel.unit(),
    )
    # anchor the sweep on the static family's footprints
    totals = sorted(
        c.total_bytes for c in planner.candidates() if c.schedule is not None
    )
    lo, hi = 0.5 * totals[0], 1.25 * totals[-1]
    span = max(1, n_points - 1)
    budgets = [lo + (hi - lo) * i / span for i in range(n_points)]
    points = []
    prev_cost = None
    for b in budgets:  # ascending: planner pool is cumulative
        d = planner.plan(b)
        points.append(
            {
                "budget_bytes": b,
                "feasible": d.feasible,
                "schedule": d.chosen.name if d.feasible else None,
                "cost": d.chosen.cost if d.feasible else None,
                "bubble_rate": d.chosen.bubble_rate if d.feasible else None,
                "total_bytes": d.chosen.total_bytes if d.feasible else None,
                "min_required_bytes": d.min_required_bytes,
            }
        )
        print(f"  {arch}: {d.summary()}")
        if d.feasible:
            if prev_cost is not None and d.chosen.cost > prev_cost + 1e-6:
                raise AssertionError(
                    f"{arch}: cost went UP with budget "
                    f"({prev_cost} -> {d.chosen.cost} at {b/2**20:.0f} MiB)"
                )
            prev_cost = d.chosen.cost
    return {
        "p": p,
        "m": m,
        "microbatch": microbatch,
        "seq_len": seq_len,
        "m_b_bytes": planner.bytes_1c.m_b_bytes,
        "points": points,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--configs", default=",".join(DEFAULT_CONFIGS))
    ap.add_argument("--points", type=int, default=8)
    ap.add_argument("--p", type=int, default=6)
    ap.add_argument("--m", type=int, default=12)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--seq-len", type=int, default=4096)
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()

    result = {}
    for arch in args.configs.split(","):
        arch = arch.strip()
        print(f"== {arch} ==")
        result[arch] = sweep(
            arch, args.p, args.m, args.microbatch, args.seq_len, args.points
        )
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
