"""Memory-vs-throughput frontier sweep (unified HBM planning layer).

For each config, build an :class:`repro.core.planner.HBMPlanner` and sweep
an ascending per-device HBM budget from just below the cheapest plan to
comfortably above the hungriest one.  At every point record the planner's
decision and its itemized breakdown (params / optim / act / wctx / inbox /
sink); the resulting cost-vs-budget curve must be monotone (more memory
never yields a slower plan -- guaranteed by the planner's cumulative
candidate pool and asserted here).

``--wall-clock`` additionally *runs* each frontier point: the chosen
schedule is executed on a fake-device mesh (``p`` host devices) with the
arch's reduced config, and the measured step time is recorded next to the
simulated cost -- the end-to-end validation of the frontier the simulator
can only predict.

Writes ``BENCH_memory_frontier.json``:

  {config: {"m_b_bytes": ..., "fixed_bytes": ..., "points": [
      {"budget_bytes", "feasible", "schedule", "cost", "bubble_rate",
       "total_bytes", "min_required_bytes", "breakdown", "wall_s"?}, ...]}}

Usage:
  python benchmarks/memory_frontier.py [--configs a,b,c] [--points N]
  python benchmarks/memory_frontier.py --wall-clock --p 4 --m 8 --points 4
"""

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))


def _prescan_int(argv, flag, default):
    for i, a in enumerate(argv):
        if a == flag and i + 1 < len(argv):
            return int(argv[i + 1])
        if a.startswith(flag + "="):
            return int(a.split("=", 1)[1])
    return default


# --wall-clock executes schedules on a fake-device mesh; the host device
# count must be pinned before jax initializes (import side effect).
# Append to any pre-existing XLA_FLAGS rather than setdefault: dropping the
# flag would leave device_count()==1 and fail the runner's device check.
if "--wall-clock" in sys.argv:
    _flag = (
        "--xla_force_host_platform_device_count="
        f"{_prescan_int(sys.argv, '--p', 6)}"
    )
    _cur = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _cur:
        os.environ["XLA_FLAGS"] = f"{_cur} {_flag}".strip()

from repro.configs import get_config, get_reduced
from repro.core.planner import HBMPlanner
from repro.core.simulator import TimeModel

DEFAULT_CONFIGS = ["gpt3_1_5b", "gpt3_6_2b", "gemma2_2b"]
OUT = os.path.join(os.path.dirname(__file__), "..", "BENCH_memory_frontier.json")


class WallClockRunner:
    """Run a schedule for real on the fake-device mesh (reduced config)."""

    def __init__(self, arch: str, p: int, m: int, seq_len: int = 32, steps: int = 2):
        import jax

        from repro.launch.mesh import AxisBinding

        self.cfg = get_reduced(arch)
        self.p, self.m = p, m
        self.seq_len = seq_len
        self.steps = steps
        if jax.device_count() < p:
            raise RuntimeError(
                f"--wall-clock needs {p} devices, have {jax.device_count()} "
                "(XLA_FLAGS was set too late?)"
            )
        self.mesh = jax.make_mesh((p,), ("data",))
        self.binding = AxisBinding(pipe="data", tp=None, dp=None)
        self._cache = {}

    def step_time(self, sched, key: str) -> float:
        """``key`` is the *plan* name (unique per dynamic search limit)."""
        if key in self._cache:
            return self._cache[key]
        import time

        import jax
        import jax.numpy as jnp

        from repro.core.schedules import compile_plan
        from repro.data import DataConfig, SyntheticLM
        from repro.launch.steps import TrainStepConfig, build_train_step
        from repro.launch.train import side_from_batch
        from repro.models.lm import RunSpec, init_params
        from repro.optim import adamw

        cfg = self.cfg
        spec = RunSpec(
            p=self.p, n_chunks=sched.n_chunks, microbatch=1,
            seq_len=self.seq_len, m=self.m,
        )
        plan = compile_plan(sched)
        make, _ = build_train_step(
            cfg, spec, plan, sched.placement, self.mesh, self.binding,
            TrainStepConfig(),
        )
        stacked, shared = init_params(cfg, spec, sched.placement)

        def zeros_like_state(tree):
            return jax.tree_util.tree_map(
                lambda a: jnp.zeros(a.shape, jnp.float32), tree
            )

        opt = adamw.AdamWState(
            t=jnp.zeros((), jnp.int32),
            m=zeros_like_state(stacked),
            v=zeros_like_state(stacked),
        )
        shared_opt = adamw.AdamWState(
            t=jnp.zeros((), jnp.int32),
            m=zeros_like_state(shared),
            v=zeros_like_state(shared),
        )
        data = SyntheticLM(
            DataConfig(
                global_batch=spec.m * spec.microbatch,
                seq_len=spec.seq_len,
                vocab=cfg.vocab,
            )
        )
        side = side_from_batch(data.batch_at(0), spec, cfg=cfg)
        step = make(side)
        state = (stacked, shared, opt, shared_opt)
        out = step(*state, side)  # compile + warm-up
        jax.block_until_ready(out)
        best = float("inf")
        for _ in range(self.steps):
            t0 = time.perf_counter()
            out = step(*out[:4], side)
            jax.block_until_ready(out)
            best = min(best, time.perf_counter() - t0)
        self._cache[key] = best
        return best


def sweep(
    arch: str,
    p: int,
    m: int,
    microbatch: int,
    seq_len: int,
    n_points: int,
    wall: "WallClockRunner | None" = None,
):
    cfg = get_config(arch)
    # xla_temp_bytes=0: this benchmark studies the *schedule-dependent*
    # memory/cost frontier; the per-config dryrun calibration (charged by
    # plan() defaults) is a constant shifting every candidate equally --
    # on the CPU-liveness numbers it would drown the v-family frontier.
    planner = HBMPlanner(
        cfg, p=p, m=m, microbatch=microbatch, seq_len=seq_len,
        times=TimeModel.unit(), xla_temp_bytes=0.0,
    )
    # anchor the sweep on the static family's full HBM footprints
    totals = sorted(
        c.total_bytes for c in planner.candidates() if c.schedule is not None
    )
    lo, hi = 0.5 * totals[0], 1.25 * totals[-1]
    span = max(1, n_points - 1)
    budgets = [lo + (hi - lo) * i / span for i in range(n_points)]
    points = []
    prev_cost = None
    for b in budgets:  # ascending: planner pool is cumulative
        d = planner.plan(b)
        point = {
            "budget_bytes": b,
            "feasible": d.feasible,
            "schedule": d.chosen.name if d.feasible else None,
            "cost": d.chosen.cost if d.feasible else None,
            "bubble_rate": d.chosen.bubble_rate if d.feasible else None,
            "total_bytes": d.chosen.total_bytes if d.feasible else None,
            "min_required_bytes": d.min_required_bytes,
            "breakdown": d.chosen.breakdown.items() if d.feasible else None,
        }
        print(f"  {arch}: {d.summary()}")
        if d.feasible and wall is not None:
            point["wall_s"] = wall.step_time(d.chosen.schedule, d.chosen.name)
            print(
                f"  {arch}: wall-clock {d.chosen.name} "
                f"{point['wall_s'] * 1e3:.0f} ms/step "
                f"(simulated cost {d.chosen.cost:.1f})"
            )
        points.append(point)
        if d.feasible:
            if prev_cost is not None and d.chosen.cost > prev_cost + 1e-6:
                raise AssertionError(
                    f"{arch}: cost went UP with budget "
                    f"({prev_cost} -> {d.chosen.cost} at {b/2**20:.0f} MiB)"
                )
            prev_cost = d.chosen.cost
    params, optim = planner.fixed_bytes(1)
    return {
        "p": p,
        "m": m,
        "microbatch": microbatch,
        "seq_len": seq_len,
        "m_b_bytes": planner.bytes_1c.m_b_bytes,
        "fixed_bytes": {"params": params, "optim": optim},
        "points": points,
    }


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--configs", default=",".join(DEFAULT_CONFIGS))
    ap.add_argument("--points", type=int, default=8)
    ap.add_argument("--p", type=int, default=6)
    ap.add_argument("--m", type=int, default=12)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--seq-len", type=int, default=4096)
    ap.add_argument(
        "--wall-clock",
        action="store_true",
        help="run each feasible point on a fake-device mesh (reduced arch) "
        "and record the measured step time next to the simulated cost",
    )
    ap.add_argument("--out", default=OUT)
    args = ap.parse_args()

    if args.wall_clock:
        # repeated wall-clock sweeps skip recompiles ($REPRO_JAX_CACHE_DIR)
        from repro.launch.compile_cache import enable_persistent_cache

        enable_persistent_cache()

    result = {}
    for arch in args.configs.split(","):
        arch = arch.strip()
        print(f"== {arch} ==")
        wall = (
            WallClockRunner(arch, args.p, args.m) if args.wall_clock else None
        )
        result[arch] = sweep(
            arch, args.p, args.m, args.microbatch, args.seq_len, args.points,
            wall=wall,
        )
    with open(args.out, "w") as f:
        json.dump(result, f, indent=2)
    print(f"wrote {args.out}")


if __name__ == "__main__":
    main()
