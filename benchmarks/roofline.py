import os
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=16")

"""Roofline analysis per (arch x shape) cell on the single-pod mesh.

Derives the three terms from compiled artifacts (TPU v5e targets):

  compute    = HLO_FLOPs / (chips * 197 TFLOP/s)
  memory     = HLO_bytes / (chips * 819 GB/s)
  collective = collective wire bytes / (chips * 50 GB/s per ICI link)

``compiled.cost_analysis()`` counts a while-loop body once, so the ticked
executors are costed per *pass*: each F/B/W (and src/sink/optimizer) pass is
compiled standalone under a TP-16 shard_map, its FLOPs/bytes/collectives
extracted, then multiplied by the schedule's static per-stage counts.  The
per-tick channel permutes of the executor are added analytically
(channels x ticks x activation bytes).  The bottleneck stage (loss stage,
which also owns the LM head) defines the reported terms.

Collective wire bytes per device use ring factors: all-reduce 2(n-1)/n x
payload, all-gather / reduce-scatter (n-1)/n, permute 1.0.

MODEL_FLOPS = 6 N_active D (train) / 2 N_active D (prefill/decode) catches
remat or redundancy waste via the ratio MODEL/HLO.
"""

import argparse
import dataclasses
import json
import re
import sys
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs import ARCH_IDS, get_config
from repro.configs.shapes import SHAPES, cells_for
from repro.core.schedules import compile_plan, zb_h2
from repro.core.schedules.ir import Placement
from repro.launch.dryrun import make_run_spec
from repro.launch.mesh import AxisBinding, make_production_mesh
from repro.launch.sharding_rules import stacked_param_specs, shared_param_specs
from repro.models.lm import (
    RunSpec,
    build_program,
    init_params,
    side_inputs,
    make_chunk_fn,
)

PEAK_FLOPS = 197e12  # bf16 / chip
HBM_BW = 819e9  # B/s
LINK_BW = 50e9  # B/s per ICI link

_COLL_FACTORS = {
    "all-reduce": lambda n: 2 * (n - 1) / n,
    "all-gather": lambda n: (n - 1) / n,
    "reduce-scatter": lambda n: (n - 1) / n,
    "all-to-all": lambda n: (n - 1) / n,
    "collective-permute": lambda n: 1.0,
}

_SHAPE_RE = re.compile(r"(bf16|f32|f16|s32|u32|s8|u8|pred|f64|s64)\[([\d,]*)\]")
_BYTES = {"bf16": 2, "f16": 2, "f32": 4, "s32": 4, "u32": 4, "s8": 1, "u8": 1,
          "pred": 1, "f64": 8, "s64": 8}


def collective_bytes(hlo_text: str, group_size: int) -> float:
    """Sum wire bytes of collectives in (non-fused) HLO text."""
    total = 0.0
    for line in hlo_text.splitlines():
        ls = line.strip()
        m = re.match(r"%?\S+\s*=\s*(\S+)\s+(all-reduce|all-gather|reduce-scatter|all-to-all|collective-permute)", ls)
        if not m:
            continue
        out_ty, op = m.group(1), m.group(2)
        sm = _SHAPE_RE.search(out_ty)
        if not sm:
            continue
        dty, dims = sm.group(1), sm.group(2)
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        payload = n * _BYTES[dty]
        total += payload * _COLL_FACTORS[op](group_size)
    return total


@dataclasses.dataclass
class PassCost:
    flops: float
    bytes: float
    coll: float


def _cost_of(fn, mesh, in_specs, out_specs, args) -> PassCost:
    wrapped = jax.jit(
        shard_map(fn, mesh=mesh, in_specs=in_specs, out_specs=out_specs,
                  check_rep=False)
    )
    lowered = wrapped.lower(*args)
    compiled = lowered.compile()
    cost = compiled.cost_analysis() or {}
    if isinstance(cost, (list, tuple)):  # one dict per device program
        cost = cost[0] if cost else {}
    text = compiled.as_text()
    tp = mesh.devices.size
    return PassCost(
        flops=float(cost.get("flops", 0.0)),
        bytes=float(cost.get("bytes accessed", 0.0)),
        coll=collective_bytes(text, tp),
    )


def _sdt(tree):
    return jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype)
        if not isinstance(a, jax.ShapeDtypeStruct)
        else a,
        tree,
    )


def _localize(sdt_tree, spec_tree, axis_sizes: Dict[str, int]):
    """Per-leaf local shard ShapeDtypeStructs for given PartitionSpecs."""

    def one(sd, spec):
        shape = list(sd.shape)
        for i, part in enumerate(spec):
            if part is None:
                continue
            names = part if isinstance(part, tuple) else (part,)
            for nm in names:
                shape[i] //= axis_sizes[nm]
        return jax.ShapeDtypeStruct(tuple(shape), sd.dtype)

    return jax.tree_util.tree_map(
        one, sdt_tree, spec_tree,
        is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
    )


def analyze_cell(
    arch_id: str,
    shape_id: str,
    verbose=True,
    b_override: Optional[int] = None,
    shard_channels: bool = False,
    wgrad_fused: bool = False,
    schedule: str = "zb-h2",
) -> Optional[dict]:
    cfg = get_config(arch_id)
    cell = SHAPES[shape_id]
    mesh = jax.make_mesh((16,), ("model",))
    binding = AxisBinding(pipe="data", tp="model", dp=None)

    class FakeMesh:  # binding.sizes needs the production shape
        axis_names = ("data", "model")
        devices = np.empty((16, 16))

    spec = make_run_spec(cfg, cell, FakeMesh(), binding, schedule)
    if b_override is not None and cell.kind == "train":
        total = spec.m * spec.microbatch
        spec = dataclasses.replace(
            spec, microbatch=b_override, m=max(1, total // b_override)
        )
    p = 16
    if cell.kind != "train":
        placement = Placement.linear(p, spec.n_chunks)
    elif schedule == "zb-v":
        from repro.core.schedules import zb_v as _zbv

        placement = _zbv(p, spec.m).placement
    else:
        placement = zb_h2(p, spec.m).placement
    sdt_params = jax.eval_shape(lambda: init_params(cfg, spec, placement))
    stacked_sdt, shared_sdt = sdt_params
    # single-stage local params: drop the stage axis from the global shapes
    stage_sdt = tuple(
        jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), sp
        )
        for sp in stacked_sdt
    )
    stage_specs = tuple(
        jax.tree_util.tree_map(lambda s: P(*s[1:]), sp)
        for sp in stacked_param_specs(stacked_sdt, "data", "model")
    )
    shared_specs = shared_param_specs(shared_sdt, "model")

    side_all = jax.eval_shape(lambda: side_inputs(cfg, spec))
    side_mb = jax.tree_util.tree_map(
        lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), side_all
    )
    side_specs = jax.tree_util.tree_map(lambda _: P(), side_mb)

    m, b = spec.m, spec.microbatch
    s_total = side_mb["positions"].shape[0]
    act = jax.ShapeDtypeStruct((b, s_total, cfg.d_model), cfg.jdtype())
    act_bytes = int(np.prod(act.shape)) * act.dtype.itemsize

    axis_sizes = {"model": 16}
    stage_local = tuple(
        _localize(sp, specs, axis_sizes)
        for sp, specs in zip(stage_sdt, stage_specs)
    )
    shared_local = _localize(shared_sdt, shared_specs, axis_sizes)

    if cell.kind == "train":
        program = build_program(cfg, spec, placement)
        mod = program.chunks[0]
        # residual structures: trace the fwd *inside* shard_map (the layers
        # contain model-axis collectives); out_specs P() => local shapes.
        f_sm = shard_map(
            lambda pr, x, sd: mod.fwd(pr, x, sd),
            mesh=mesh,
            in_specs=(stage_specs[0], P(), side_specs),
            out_specs=P(),
            check_rep=False,
        )
        y_sh, res_sh = jax.eval_shape(f_sm, stage_sdt[0], act, side_mb)
        b_sm = shard_map(
            lambda pr, r, g, sd: mod.bwd_x(pr, r, g, sd),
            mesh=mesh,
            in_specs=(stage_specs[0], P(), P(), side_specs),
            out_specs=P(),
            check_rep=False,
        )
        dx_sh, wctx_sh = jax.eval_shape(b_sm, stage_sdt[0], res_sh, act, side_mb)

        cF = _cost_of(
            lambda pr, x, sd: mod.fwd(pr, x, sd),
            mesh, (stage_specs[0], P(), side_specs), P(),
            (stage_sdt[0], act, side_mb),
        )
        cB = _cost_of(
            lambda pr, r, g, sd: mod.bwd_x(pr, r, g, sd),
            mesh, (stage_specs[0], P(), P(), side_specs), P(),
            (stage_sdt[0], res_sh, act, side_mb),
        )
        cW = _cost_of(
            lambda pr, w, sd: mod.bwd_w(pr, w, sd),
            mesh, (stage_specs[0], P(), side_specs), stage_specs[0],
            (stage_sdt[0], wctx_sh, side_mb),
        )
        # sink (final norm + head + CE) fwd+bwd on the loss stage
        sink = program.sink
        s_sm = shard_map(
            lambda sh, y, sd: sink.fwd(sh, y, sd),
            mesh=mesh,
            in_specs=(shared_specs, P(), side_specs),
            out_specs=P(),
            check_rep=False,
        )
        loss_sh, sres_sh = jax.eval_shape(s_sm, shared_sdt, act, side_mb)
        cSink = _cost_of(
            lambda sh, y, sd: sink.fwd(sh, y, sd),
            mesh, (shared_specs, P(), side_specs), P(),
            (shared_sdt, act, side_mb),
        )
        ones = jax.ShapeDtypeStruct(loss_sh.shape, loss_sh.dtype)
        sb_sm = shard_map(
            lambda sh, r, g, sd: sink.bwd_x(sh, r, g, sd),
            mesh=mesh,
            in_specs=(shared_specs, P(), P(), side_specs),
            out_specs=P(),
            check_rep=False,
        )
        _, swctx_sh = jax.eval_shape(sb_sm, shared_sdt, sres_sh, ones, side_mb)
        cSinkB = _cost_of(
            lambda sh, r, g, sd: sink.bwd_x(sh, r, g, sd),
            mesh, (shared_specs, P(), P(), side_specs), P(),
            (shared_sdt, sres_sh, ones, side_mb),
        )
        cSinkW = _cost_of(
            lambda sh, w, sd: sink.bwd_w(sh, w, sd),
            mesh, (shared_specs, P(), side_specs), shared_specs,
            (shared_sdt, swctx_sh, side_mb),
        )
        from repro.core.schedules import zb_v as _zbv

        sched_obj = _zbv(p, spec.m) if schedule == "zb-v" else zb_h2(p, spec.m)
        plan = compile_plan(sched_obj)
        T = plan.n_ticks
        n_chan = len(plan.used_channels())
        C = spec.n_chunks
        # bottleneck stage = loss stage: m*(F+B+W) per chunk + m*sink passes
        flops = C * m * (cF.flops + cB.flops + cW.flops) + m * (
            cSink.flops + cSinkB.flops + cSinkW.flops
        )
        byts = C * m * (cF.bytes + cB.bytes + cW.bytes) + m * (
            cSink.bytes + cSinkB.bytes + cSinkW.bytes
        )
        # gradient-accumulator HBM traffic (the executor's grad_acc += g is
        # outside the costed passes): unfused = read g + read acc + write acc;
        # the fused Pallas wgrad kernel (kernels/wgrad_accum.py) keeps the
        # accumulate in the matmul epilogue: read acc + write acc only, and
        # the separate g materialization disappears.
        params_local = sum(
            int(np.prod(l.shape)) for l in jax.tree_util.tree_leaves(stage_local[0])
        )
        acc_traffic = (3 if not wgrad_fused else 1) * params_local * 4
        byts = byts + C * m * acc_traffic
        # per-axis collective wire bytes: per-pass psums run over the TP
        # links; channel permutes run over the pipe links.  Sequence-sharded
        # channels divide pipe bytes by tp and add one (tp-1)/tp all-gather
        # per consumed F/B input on the TP links.
        tp_n = 16
        coll_tp = C * m * (cF.coll + cB.coll + cW.coll) + m * (
            cSink.coll + cSinkB.coll + cSinkW.coll
        )
        chan_bytes = n_chan * T * act_bytes
        if shard_channels:
            chan_bytes /= tp_n
            coll_tp += 2 * C * m * act_bytes * (tp_n - 1) / tp_n
        coll_pipe = chan_bytes
        coll = coll_tp + coll_pipe
        detail = {
            "F": dataclasses.asdict(cF), "B": dataclasses.asdict(cB),
            "W": dataclasses.asdict(cW), "sinkF": dataclasses.asdict(cSink),
            "sinkB": dataclasses.asdict(cSinkB), "sinkW": dataclasses.asdict(cSinkW),
            "ticks": T, "channels": n_chan,
            "coll_tp": coll_tp, "coll_pipe": coll_pipe,
            "acc_traffic": C * m * acc_traffic,
            "b": spec.microbatch, "m": spec.m,
        }
    else:
        from repro.models.serve import build_serve_program
        from repro.core.infer_executor import compile_infer_plan

        mode = "prefill" if cell.kind == "prefill" else "decode"
        program, cache_init, cache_pspecs = build_serve_program(
            cfg, spec, placement, mode
        )
        cache_sh = jax.eval_shape(lambda: cache_init(b, cell.seq_len))
        kind_specs = cache_pspecs("model")
        cache_specs = jax.tree_util.tree_map(
            lambda sd, ks: ks,
            cache_sh,
            kind_specs,
            is_leaf=lambda x: isinstance(x, jax.ShapeDtypeStruct),
        )
        x_sh = (
            act
            if mode == "prefill"
            else jax.ShapeDtypeStruct((b, 1, cfg.d_model), cfg.jdtype())
        )
        if mode == "decode":
            side_mb = {
                "tokens": jax.ShapeDtypeStruct((b, 1), jnp.int32),
                "positions": jax.ShapeDtypeStruct((1,), jnp.int32),
            }
            side_specs = jax.tree_util.tree_map(lambda _: P(), side_mb)
        pos = cell.seq_len - 1 if mode == "decode" else 0
        cPass = _cost_of(
            lambda pr, x, sd, cc: program.chunk_fns[0](pr, x, sd, cc, pos),
            mesh,
            (stage_specs[0], P(), side_specs, cache_specs),
            (P(), cache_specs),
            (stage_sdt[0], x_sh, side_mb, cache_sh),
        )
        cSink = _cost_of(
            lambda sh, y, sd: program.sink(sh, y, sd),
            mesh, (shared_specs, P(), side_specs), P(),
            (shared_sdt, x_sh, side_mb),
        )
        plan = compile_infer_plan(placement, spec.m)
        T = plan.n_ticks
        tok_bytes = (
            act_bytes
            if mode == "prefill"
            else int(b * cfg.d_model) * act.dtype.itemsize
        )
        flops = spec.n_chunks * m * cPass.flops + m * cSink.flops
        byts = spec.n_chunks * m * cPass.bytes + m * cSink.bytes
        tp_n = 16
        coll_tp = spec.n_chunks * m * cPass.coll + m * cSink.coll
        chan_bytes = 2 * T * tok_bytes
        if shard_channels and mode == "prefill":
            chan_bytes /= tp_n
            coll_tp += 2 * spec.n_chunks * m * act_bytes * (tp_n - 1) / tp_n
        coll_pipe = chan_bytes
        coll = coll_tp + coll_pipe
        detail = {
            "pass": dataclasses.asdict(cPass),
            "sink": dataclasses.asdict(cSink),
            "ticks": T,
            "coll_tp": coll_tp, "coll_pipe": coll_pipe,
            "b": spec.microbatch, "m": spec.m,
        }

    chips = 256
    t_compute = flops / PEAK_FLOPS  # per-device flops already
    t_memory = byts / HBM_BW
    # pipe and tp traffic ride different physical links: bound = max
    t_coll = max(coll_tp, coll_pipe) / LINK_BW
    dominant = max(
        ("compute", t_compute), ("memory", t_memory), ("collective", t_coll),
        key=lambda kv: kv[1],
    )[0]

    n_active = active_params(cfg)
    tokens = cell.global_batch * cell.seq_len if cell.kind != "decode" else cell.global_batch
    model_flops = (6 if cell.kind == "train" else 2) * n_active * tokens
    hlo_total = flops * chips  # per-device x chips (uniform by stage approx)
    result = {
        "arch": arch_id,
        "shape": shape_id,
        "kind": cell.kind,
        "t_compute_s": t_compute,
        "t_memory_s": t_memory,
        "t_collective_s": t_coll,
        "dominant": dominant,
        "flops_per_device": flops,
        "bytes_per_device": byts,
        "coll_bytes_per_device": coll,
        "coll_tp_bytes": coll_tp,
        "coll_pipe_bytes": coll_pipe,
        "opts": {
            "b_override": b_override,
            "shard_channels": shard_channels,
            "wgrad_fused": wgrad_fused,
            "schedule": schedule,
        },
        "model_flops": model_flops,
        "hlo_flops_total": hlo_total,
        "useful_ratio": model_flops / hlo_total if hlo_total else None,
        "detail": detail,
    }
    if verbose:
        print(json.dumps({k: v for k, v in result.items() if k != "detail"}))
        sys.stdout.flush()
    return result


def active_params(cfg) -> float:
    """Analytic active-parameter count (MoE counts topk + shared experts)."""
    h, L = cfg.d_model, cfg.n_layers
    ex = cfg.extras_dict()
    dh = cfg.head_dim or h // cfg.n_heads
    total = 0.0
    for i in range(L):
        kinds = cfg.block_pattern[i % cfg.period]
        for kind in kinds:
            if kind in ("attn", "attn_local"):
                total += h * dh * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * dh * h
            elif kind == "mla":
                dq = ex.get("q_lora_rank", 1536)
                dkv = ex.get("kv_lora_rank", 512)
                dr = ex.get("qk_rope_head_dim", 64)
                total += (
                    h * dq + dq * cfg.n_heads * (dh + dr) + h * (dkv + dr)
                    + 2 * dkv * cfg.n_heads * dh + cfg.n_heads * dh * h
                )
            elif kind == "mlp":
                total += 3 * h * cfg.d_ff
            elif kind == "moe":
                f = ex["moe_d_ff"]
                act_e = ex["topk"] + ex.get("n_shared_experts", 0)
                total += act_e * 3 * h * f + h * ex["n_experts"]
            elif kind in ("slstm", "mlstm"):
                total += 5 * h * h
            elif kind == "rglru":
                dr = ex.get("lru_width", h)
                total += 2 * h * dr + 2 * dr * dr + dr * h
            elif kind == "encdec":
                total += 3 * (h * dh * (cfg.n_heads + 2 * cfg.n_kv_heads) + cfg.n_heads * dh * h) / 1.5 + 2 * 3 * h * cfg.d_ff
    total += 2 * cfg.vocab * h  # embed + head
    return total


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="all")
    ap.add_argument("--shape", default="all")
    ap.add_argument("--out", default="results/roofline.json")
    args = ap.parse_args()
    archs = ARCH_IDS if args.arch == "all" else [args.arch]
    results = []
    for arch in archs:
        for sid, cell, skip in cells_for(arch):
            if args.shape != "all" and sid != args.shape:
                continue
            if skip:
                results.append({"arch": arch, "shape": sid, "skipped": skip})
                continue
            try:
                results.append(analyze_cell(arch, sid))
            except Exception as e:
                import traceback

                traceback.print_exc()
                results.append({"arch": arch, "shape": sid, "error": str(e)[:300]})
    with open(args.out, "w") as f:
        json.dump(results, f, indent=1)
    bad = [r for r in results if "error" in r]
    print(f"{len(results)-len(bad)}/{len(results)} roofline cells OK")


if __name__ == "__main__":
    main()
