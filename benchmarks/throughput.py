"""Measured training throughput: generic vs specialized tick executor.

Runs the full jitted train step (pipeline executor + postval AdamW) for
each schedule family on a fake-device mesh, in both executor compilation
modes (DESIGN.md Sec. 8):

  * ``scan``        -- the generic one-tick-body executor (baseline),
  * ``specialized`` -- trace-time specialization against the static plan.

Reports steady-state steps/s (min-of-repeats wall time, first compile
excluded and recorded separately) and asserts the two modes are
*bit-identical*: same loss, same grad norm, same updated parameters.

Writes ``BENCH_throughput.json`` -- the repo's perf trajectory; CI runs
the smoke point and fails when the specialized executor is slower than
the generic one (``--enforce``).

Example (the CI smoke point):
  python benchmarks/throughput.py --smoke --enforce
"""

import argparse
import json
import math
import os
import sys
import time

# the host device count must be pinned before jax initializes (import side
# effect).  Append to any pre-existing XLA_FLAGS rather than setdefault:
# dropping the flag would leave device_count()==1 and fail mesh creation.
_P_DEFAULT = 8
if "--help" not in sys.argv and "-h" not in sys.argv:
    _p = _P_DEFAULT
    for i, a in enumerate(sys.argv):
        if a == "--p" and i + 1 < len(sys.argv):
            _p = int(sys.argv[i + 1])
        elif a.startswith("--p="):
            _p = int(a.split("=", 1)[1])
    _cur = os.environ.get("XLA_FLAGS", "")
    if "--xla_force_host_platform_device_count" not in _cur:
        os.environ["XLA_FLAGS"] = (
            f"{_cur} --xla_force_host_platform_device_count={_p}".strip()
        )

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np


def parse_args():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="internlm2_1_8b")
    ap.add_argument("--p", type=int, default=_P_DEFAULT)
    ap.add_argument("--m", type=int, default=16)
    ap.add_argument("--microbatch", type=int, default=1)
    ap.add_argument("--seq-len", type=int, default=32)
    ap.add_argument(
        "--schedules",
        default="1f1b,zb-h1,zb-v,v-min",
        help="comma-separated schedule families",
    )
    ap.add_argument("--steps", type=int, default=8, help="timed steps per rep")
    ap.add_argument("--reps", type=int, default=3, help="take the fastest rep")
    ap.add_argument("--out", default=None, help="default: repo-root BENCH_throughput.json")
    ap.add_argument(
        "--smoke",
        action="store_true",
        help="CI-sized run: fewer timed steps, smaller m",
    )
    ap.add_argument(
        "--enforce",
        action="store_true",
        help="exit 1 when the specialized executor is not faster than the "
        "generic scan executor (geomean over families)",
    )
    return ap.parse_args()


def build_step(cfg, spec, plan, placement, mesh, binding, mode):
    from repro.launch.steps import TrainStepConfig, build_train_step

    tcfg = TrainStepConfig(executor_mode=mode, donate=True)
    make, _ = build_train_step(cfg, spec, plan, placement, mesh, binding, tcfg)
    return make


def init_state(cfg, spec, placement):
    from repro.models.lm import init_params
    from repro.optim import adamw

    stacked, shared = init_params(cfg, spec, placement)
    return stacked, shared, adamw.init(stacked), adamw.init(shared)


def copy_state(state):
    return jax.tree_util.tree_map(jnp.copy, state)


def main():
    args = parse_args()
    if args.smoke:
        args.m = min(args.m, 12)
        args.steps = min(args.steps, 5)
        args.reps = min(args.reps, 2)

    from repro.configs import get_reduced
    from repro.core.schedules import compile_plan
    from repro.data import DataConfig, SyntheticLM
    from repro.launch.compile_cache import enable_persistent_cache
    from repro.launch.mesh import AxisBinding
    from repro.launch.train import SCHEDULES, side_from_batch
    from repro.models.lm import RunSpec

    cache_dir = enable_persistent_cache()
    cfg = get_reduced(args.arch)
    p, m = args.p, args.m
    mesh = jax.make_mesh((p,), ("data",))
    binding = AxisBinding(pipe="data", tp=None, dp=None)

    results = []
    speedups = []
    for sched_name in args.schedules.split(","):
        sched = SCHEDULES[sched_name](p, m)
        plan = compile_plan(sched)
        sw = plan.steady_window()
        spec = RunSpec(
            p=p,
            n_chunks=sched.n_chunks,
            microbatch=args.microbatch,
            seq_len=args.seq_len,
            m=m,
        )
        data = SyntheticLM(
            DataConfig(
                global_batch=m * args.microbatch,
                seq_len=args.seq_len,
                vocab=cfg.vocab,
            )
        )
        side = side_from_batch(data.batch_at(0), spec, cfg=cfg)
        state0 = init_state(cfg, spec, sched.placement)

        per_mode = {}
        parity = {}
        for mode in ("scan", "specialized"):
            make = build_step(
                cfg, spec, plan, sched.placement, mesh, binding, mode
            )
            step = make(side)

            # compile + first step (the jitted step donates its inputs, so
            # every call gets a fresh copy of the identical initial state)
            t0 = time.perf_counter()
            out = step(*copy_state(state0), side)
            jax.block_until_ready(out)
            compile_s = time.perf_counter() - t0

            parity[mode] = dict(
                loss=np.asarray(out[4]["loss"]).item(),
                grad_norm=np.asarray(out[4]["grad_norm"]).item(),
                params=[
                    np.asarray(l)
                    for l in jax.tree_util.tree_leaves(out[0])
                ],
            )

            # steady-state timing: chain the state through timed steps;
            # min over reps rejects scheduler noise on shared CI hosts
            chained = out[:4]
            best = math.inf
            for _ in range(args.reps):
                t0 = time.perf_counter()
                for _ in range(args.steps):
                    o = step(*chained, side)
                    chained = o[:4]
                jax.block_until_ready(chained)
                best = min(best, (time.perf_counter() - t0) / args.steps)
            per_mode[mode] = dict(
                step_time_s=best,
                steps_per_s=1.0 / best,
                compile_s=compile_s,
            )
            print(
                f"{sched_name:8s} {mode:12s} step {best*1e3:8.2f} ms  "
                f"({1.0/best:6.2f} steps/s)  compile {compile_s:6.1f}s"
            )

        # -- bit-identical parity across executor modes ------------------- #
        a, b = parity["scan"], parity["specialized"]
        assert a["loss"] == b["loss"], (
            f"{sched_name}: loss differs {a['loss']} vs {b['loss']}"
        )
        assert a["grad_norm"] == b["grad_norm"], f"{sched_name}: grad_norm differs"
        for la, lb in zip(a["params"], b["params"]):
            np.testing.assert_array_equal(la, lb)
        print(f"{sched_name:8s} parity: bit-identical loss/grads/params")

        speedup = (
            per_mode["scan"]["step_time_s"]
            / per_mode["specialized"]["step_time_s"]
        )
        speedups.append(speedup)
        results.append(
            dict(
                schedule=sched_name,
                n_ticks=plan.n_ticks,
                steady_window=(
                    dict(start=sw.start, period=sw.period, repeats=sw.repeats)
                    if sw
                    else None
                ),
                generic=per_mode["scan"],
                specialized=per_mode["specialized"],
                speedup=speedup,
                loss=a["loss"],
                parity_bit_identical=True,
            )
        )
        print(f"{sched_name:8s} speedup x{speedup:.2f}")

    geomean = math.exp(sum(math.log(s) for s in speedups) / len(speedups))
    payload = dict(
        benchmark="throughput",
        config=dict(
            arch=cfg.name,
            reduced=True,
            p=p,
            m=m,
            microbatch=args.microbatch,
            seq_len=args.seq_len,
            steps=args.steps,
            reps=args.reps,
            backend=jax.default_backend(),
            devices=jax.device_count(),
            compile_cache=cache_dir,
        ),
        results=results,
        geomean_speedup=geomean,
    )
    out_path = args.out or os.path.join(
        os.path.dirname(__file__), "..", "BENCH_throughput.json"
    )
    with open(out_path, "w") as f:
        json.dump(payload, f, indent=2)
    print(f"geomean speedup x{geomean:.2f} -> {os.path.abspath(out_path)}")

    if args.enforce and geomean <= 1.0:
        print("FAIL: specialized executor is not faster than generic")
        sys.exit(1)


if __name__ == "__main__":
    main()
