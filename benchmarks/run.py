"""Benchmark harness: one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.  ``us_per_call`` is the wall
time of one evaluation of the underlying machinery on this host;
``derived`` carries the reproduced quantity (bubble rate, ratio, ...) and the
paper's reference value where one exists.

Tables covered: 2 (closed forms), 4 (throughput ratios), 5 (bubble rates),
8 (ZB-V rates), 10 (post-validation ablation), 12 (m <= p), Figs. 7/9
(memory-limit sweeps).  Roofline terms come from benchmarks/roofline.py
(separate entrypoint; results in results/roofline.json).
"""

import json
import math
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import numpy as np

from repro.core.schedules import (
    gpipe,
    interleaved_1f1b,
    one_f_one_b,
    search,
    zb_h1,
    zb_h2,
    zb_v,
)
from repro.core.simulator import TimeModel, simulate

ROWS = []


def emit(name, us, derived):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}")
    sys.stdout.flush()


def timed(fn):
    t0 = time.perf_counter()
    out = fn()
    return out, (time.perf_counter() - t0) * 1e6


# paper Table 9 profiled times; Table 5 reference bubble rates
T9 = {
    ("1.5B", 24): (8, 18.522, 18.086, 9.337, 0.601),
    ("1.5B", 32): (8, 18.513, 18.086, 9.331, 0.626),
    ("1.5B", 64): (8, 18.546, 18.097, 9.321, 0.762),
    ("6.2B", 24): (8, 29.718, 29.444, 19.927, 0.527),
    ("6.2B", 32): (8, 29.802, 29.428, 19.530, 0.577),
    ("6.2B", 64): (8, 29.935, 29.621, 19.388, 0.535),
    ("14.6B", 48): (16, 11.347, 11.248, 8.132, 0.377),
    ("14.6B", 64): (16, 11.307, 11.254, 8.101, 0.379),
    ("14.6B", 128): (16, 11.325, 11.308, 8.109, 0.378),
    ("28.3B", 96): (32, 10.419, 10.207, 7.715, 0.408),
    ("28.3B", 128): (32, 10.408, 10.204, 7.703, 0.408),
    ("28.3B", 256): (32, 10.402, 10.248, 7.698, 0.460),
}
T5_REF = {  # (1f1b, zb-1p, zb-2p) per (model, m)
    ("1.5B", 24): (0.2431, 0.1585, 0.0433),
    ("1.5B", 32): (0.1985, 0.1242, 0.0039),
    ("1.5B", 64): (0.1240, 0.0674, 0.0026),
    ("6.2B", 24): (0.2347, 0.1323, 0.0029),
    ("6.2B", 32): (0.1898, 0.1045, 0.0022),
    ("6.2B", 64): (0.1091, 0.0554, 0.0010),
    ("14.6B", 48): (0.2552, 0.1397, 0.0066),
    ("14.6B", 64): (0.2082, 0.1088, 0.0054),
    ("14.6B", 128): (0.1251, 0.0576, 0.0028),
    ("28.3B", 96): (0.2646, 0.1421, 0.0038),
    ("28.3B", 128): (0.2168, 0.1106, 0.0029),
    ("28.3B", 256): (0.1352, 0.0594, 0.0018),
}
T4_THROUGHPUT = {  # paper samples/GPU/s: (1f1b, zb-2p)
    ("1.5B", 24): (11.8, 14.5),
    ("6.2B", 24): (3.50, 4.32),
    ("14.6B", 48): (1.40, 1.81),
    ("28.3B", 96): (0.76, 0.99),
}


def table2_closed_forms():
    p, m = 8, 24
    tm = TimeModel(1.0, 1.0, 1.0, 0.0)
    tmg = TimeModel(1.0, 1.0, 1.0, 0.0, grouped_w=True)
    r, us = timed(lambda: simulate(one_f_one_b(p, m), tmg).bubble_size)
    emit("table2/1f1b_bubble", us, f"{r:.2f} (formula {(p-1)*3.0})")
    r, us = timed(lambda: simulate(zb_h1(p, m), tm).bubble_size)
    emit("table2/zb-h1_bubble", us, f"{r:.2f} (formula {(p-1)*1.0})")
    r, us = timed(lambda: simulate(zb_h2(p, m), tm).bubble_size)
    emit("table2/zb-h2_bubble", us, f"{r:.2f} (formula 0.0)")
    mp = zb_h2(p, m).memory_profile(1.0, 0.5).max_peak
    emit("table2/zb-h2_peakmem", 0.0, f"{mp:.1f} (formula {2*p-1})")


def table5_bubble_rates():
    for (model, m), (p, tf, tb, tw, tc) in T9.items():
        tm = TimeModel(tf, tb, tw, tc)
        tmg = TimeModel(tf, tb, tw, tc, grouped_w=True)
        ref = T5_REF[(model, m)]
        r, us = timed(lambda: simulate(one_f_one_b(p, m), tmg).bubble_rate)
        emit(f"table5/{model}/m{m}/1f1b", us, f"{r:.4f} (paper {ref[0]:.4f})")
        r, us = timed(lambda: search(p, m, tm, m_limit=float(p)).bubble_rate)
        emit(f"table5/{model}/m{m}/zb-1p", us, f"{r:.4f} (paper {ref[1]:.4f})")
        r, us = timed(lambda: search(p, m, tm, m_limit=2.0 * p).bubble_rate)
        emit(f"table5/{model}/m{m}/zb-2p", us, f"{r:.4f} (paper {ref[2]:.4f})")


def table4_throughput_ratios():
    """Predicted ZB-2p/1F1B speedup from schedule costs vs paper's measured."""
    for (model, m), (tput_1f1b, tput_zb) in T4_THROUGHPUT.items():
        p, tf, tb, tw, tc = T9[(model, m)]
        tm = TimeModel(tf, tb, tw, tc)
        tmg = TimeModel(tf, tb, tw, tc, grouped_w=True)

        def ratio():
            c1 = simulate(one_f_one_b(p, m), tmg).cost
            c2 = search(p, m, tm, m_limit=2.0 * p).cost
            return c1 / c2

        r, us = timed(ratio)
        paper = tput_zb / tput_1f1b
        emit(
            f"table4/{model}/m{m}/speedup_zb2p_vs_1f1b",
            us,
            f"{r:.3f} (paper measured {paper:.3f})",
        )


def table8_zbv_rates():
    # Table 8 ref values (6.2B p=16 block); profiled-time inputs for these
    # runs are not published -- 6.2B p=8 times stand in (EXPERIMENTS.md).
    refs = {(16, 48): 0.0697, (16, 64): 0.0533, (16, 128): 0.0274}
    tm = TimeModel(29.718, 29.444, 19.927, 0.527)
    for (p, m), ref in refs.items():
        r, us = timed(lambda: simulate(zb_v(p, m, tm), tm).bubble_rate)
        emit(
            f"table8/zb-v/p{p}/m{m}",
            us,
            f"{r:.4f} (paper {ref:.4f}, substitute times)",
        )


def fig7_memory_sweep():
    p, m = 8, 32
    tf, tb, tw, tc = 18.513, 18.086, 9.331, 0.626
    tm = TimeModel(tf, tb, tw, tc)
    pts = []
    for lim in [p, 1.25 * p, 1.5 * p, 1.75 * p, 2 * p, 2.5 * p, 3 * p]:
        r = search(p, m, tm, m_limit=float(lim)).bubble_rate
        pts.append((round(lim / p, 2), round(r, 4)))
    emit("fig7/memory_sweep_1.5B_m32", 0.0, json.dumps(pts).replace(",", ";"))
    assert pts[0][1] > pts[-1][1]
    assert abs(pts[4][1] - pts[-1][1]) < 0.02, "should plateau by 2p"


def fig9_zbv_memory_sweep():
    p, m = 16, 48
    tm = TimeModel(29.718, 29.444, 19.927, 0.527)
    pts = []
    for lim in [p, 1.5 * p, 2 * p]:
        r = simulate(zb_v(p, m, tm, m_limit=float(lim)), tm).bubble_rate
        pts.append((round(lim / p, 2), round(r, 4)))
    emit("fig9/zbv_memory_sweep", 0.0, json.dumps(pts).replace(",", ";"))


def table10_postval_ablation():
    """Structural ablation: a blocking all-reduce at the optimizer boundary
    stalls every stage until the slowest stage's last W; post-validation
    replaces it with a pipelined relay that overlaps the W tail."""
    p, m = 8, 24
    tf, tb, tw, tc = 18.522, 18.086, 9.337, 0.601
    tm = TimeModel(tf, tb, tw, tc)
    res = search(p, m, tm, m_limit=2.0 * p)
    sim = simulate(res.schedule, tm)
    last_end = max(sim.end.values())
    per_stage_end = [
        max(sim.end[(s, op)] for op in res.schedule.stage_ops[s])
        for s in range(p)
    ]
    stall = sum(last_end - e for e in per_stage_end) / p + 2 * math.log2(p) * tc
    emit(
        "table10/postval_vs_allreduce",
        0.0,
        f"avg stall removed {stall:.1f} = {100*stall/sim.cost:.1f}% of iter (paper ~8%)",
    )


def table12_small_m():
    tm = TimeModel(1.0, 1.0, 0.9, 0.0)
    tmg = TimeModel(1.0, 1.0, 0.9, 0.0, grouped_w=True)
    for p, m in [(8, 2), (8, 4), (8, 8)]:
        c1 = simulate(one_f_one_b(p, m), tmg).cost
        c2 = search(p, m, tm, m_limit=2.0 * p).cost
        emit(
            f"table12/p{p}/m{m}/speedup",
            0.0,
            f"{c1/c2:.3f} (paper reports 1.2-1.3x for m<=p)",
        )


def scheduler_microbench():
    p, m = 32, 256
    tm = TimeModel(10.4, 10.2, 7.7, 0.41)
    _, us = timed(lambda: zb_h2(p, m))
    emit("micro/zb_h2_construct_p32_m256", us, "handcrafted")
    _, us = timed(lambda: simulate(zb_h2(p, m), tm))
    emit("micro/simulate_p32_m256", us, f"{3*p*m} ops")


def executor_tick_microbench():
    """us per executor tick on this host (CPU; structural figure only)."""
    import jax
    import jax.numpy as jnp
    from jax.sharding import PartitionSpec as P
    from jax.experimental.shard_map import shard_map

    from repro.configs import get_reduced
    from repro.core.executor import PipelineExecutor
    from repro.core.schedules import compile_plan
    from repro.models.lm import RunSpec, build_program, init_params, side_inputs

    cfg = get_reduced("gpt3_1_5b")
    p, m = 1, 4
    sched = zb_h2(p, m)
    plan = compile_plan(sched)
    spec = RunSpec(p=p, n_chunks=1, microbatch=2, seq_len=32, m=m)
    program = build_program(cfg, spec, sched.placement)
    stacked, shared = init_params(cfg, spec, sched.placement)
    side = side_inputs(cfg, spec)
    execu = PipelineExecutor(program, plan, pipe_axis="pipe")
    grad_fn = execu.build_grad_fn()
    mesh = jax.make_mesh((p,), ("pipe",))

    def body(st, sh, sd):
        local = tuple(jax.tree_util.tree_map(lambda a: a[0], x) for x in st)
        return grad_fn(local, sh, sd)[2]

    fn = jax.jit(
        shard_map(
            body,
            mesh=mesh,
            in_specs=(
                tuple(
                    jax.tree_util.tree_map(lambda _: P("pipe"), x) for x in stacked
                ),
                P(),
                P(),
            ),
            out_specs=P(),
            check_rep=False,
        )
    )
    fn(stacked, shared, side).block_until_ready()
    t0 = time.perf_counter()
    n = 5
    for _ in range(n):
        fn(stacked, shared, side).block_until_ready()
    us = (time.perf_counter() - t0) / n / plan.n_ticks * 1e6
    emit("micro/executor_us_per_tick_cpu", us, f"{plan.n_ticks} ticks/step")


def main() -> None:
    print("name,us_per_call,derived")
    table2_closed_forms()
    table5_bubble_rates()
    table4_throughput_ratios()
    table8_zbv_rates()
    fig7_memory_sweep()
    fig9_zbv_memory_sweep()
    table10_postval_ablation()
    table12_small_m()
    scheduler_microbench()
    executor_tick_microbench()
    print(f"# {len(ROWS)} benchmark rows", file=sys.stderr)


if __name__ == "__main__":
    main()
