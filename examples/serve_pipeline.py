"""Pipelined serving: prefill a batch of requests, then decode tokens.

  PYTHONPATH=src python examples/serve_pipeline.py
"""
import os, sys
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import time

import jax
import jax.numpy as jnp

from repro.configs import get_reduced
from repro.core.infer_executor import InferExecutor, compile_infer_plan
from repro.core.schedules.ir import Placement
from repro.launch.mesh import AxisBinding
from repro.launch.steps import build_serve_step
from repro.models.lm import RunSpec, init_params, side_inputs

P_, M_, B_, S_CTX, N_NEW = 4, 8, 2, 32, 8
cfg = get_reduced("internlm2_1_8b")
placement = Placement.linear(P_)
spec = RunSpec(p=P_, n_chunks=1, microbatch=B_, seq_len=S_CTX - N_NEW, m=M_)
mesh = jax.make_mesh((P_,), ("data",))
binding = AxisBinding(pipe="data", tp=None, dp=None)

# ---- prefill: build caches for m request groups ------------------------ #
make_p, prog_p, cache_init = build_serve_step(
    cfg, spec, placement, mesh, binding, "prefill", S_CTX
)
stacked, shared = init_params(cfg, spec, placement)
one = cache_init(B_, S_CTX)
caches = [jax.tree_util.tree_map(
    lambda a: jnp.zeros((P_, M_) + a.shape, a.dtype), one)]
side = side_inputs(cfg, spec)
prefill = make_p(stacked, shared, side, caches)
t0 = time.time()
logits, caches = prefill(stacked, shared, side, caches)
print(f"prefill: {M_} groups x {B_} seqs x {spec.seq_len} tokens "
      f"in {time.time()-t0:.2f}s; logits {logits.shape}")

# ---- decode: N_NEW pipelined single-token steps ------------------------ #
toks = jnp.argmax(logits, -1)[..., None]  # greedy next token per sequence
out_tokens = [toks]
for i in range(N_NEW):
    dspec = RunSpec(p=P_, n_chunks=1, microbatch=B_, seq_len=1, m=M_)
    make_d, _, _ = build_serve_step(
        cfg, dspec, placement, mesh, binding, "decode", spec.seq_len + 1 + i
    )
    dside = {
        "tokens": toks.astype(jnp.int32),
        "positions": jnp.broadcast_to(jnp.arange(1), (M_, 1)),
    }
    decode = make_d(stacked, shared, dside, caches)
    t0 = time.time()
    logits, caches = decode(stacked, shared, dside, caches)
    toks = jnp.argmax(logits, -1)[..., None]
    out_tokens.append(toks)
    print(f"decode step {i}: {M_*B_} tokens in {time.time()-t0:.3f}s")
print("generated:", jnp.concatenate(out_tokens, -1)[0, 0].tolist())
print("OK")
