"""End-to-end driver: train a ~100M-param GPT through the ZB pipeline.

Default: 4 pipeline stages (fake CPU devices), ZB-H2 schedule, synthetic
next-token stream, checkpoint/restart via the fault-tolerant driver.

  PYTHONPATH=src python examples/train_100m.py --steps 300     # full run
  PYTHONPATH=src python examples/train_100m.py --steps 5       # smoke
"""
import os, sys
os.environ.setdefault("XLA_FLAGS", "--xla_force_host_platform_device_count=4")
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import argparse
import dataclasses

from repro.models.lm import ArchConfig


def gpt_100m() -> ArchConfig:
    # ~101M params: 10 x (12 d^2) + 2 V d = 10*12*640^2 + 2*32768*640
    return ArchConfig(
        name="gpt-100m", family="dense", n_layers=10, d_model=640,
        n_heads=10, n_kv_heads=10, d_ff=2560, vocab=32768,
        block_pattern=(("attn", "mlp"),), dtype="float32",
        source="examples/train_100m",
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--schedule", default="zb-h2")
    args = ap.parse_args()

    # register the config inline and reuse the generic launcher
    import repro.launch.train as T

    cfg = gpt_100m()
    n_params = 10 * 12 * 640 * 640 + 2 * 32768 * 640
    print(f"model: {cfg.name} (~{n_params/1e6:.0f}M params)")

    orig_get = T.get_config
    T.get_config = lambda a: cfg if a == "gpt-100m" else orig_get(a)
    sys.argv = [
        "train", "--arch", "gpt-100m", "--pipe-size", "4",
        "--schedule", args.schedule, "--microbatch", "1", "--seq-len", "256",
        "--m", "8", "--steps", str(args.steps), "--lr", "1e-3",
        "--ckpt-dir", "/tmp/repro_100m_ckpt",
    ]
    T.main()


if __name__ == "__main__":
    main()
