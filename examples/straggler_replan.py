"""Straggler mitigation: op re-planning + layer rebalancing.

A uniformly slow stage bounds the iteration from below (no op order can
shrink its busy time); the fix is moving layers off it and re-searching the
ZB schedule for the new profile -- then the elastic checkpoint reshard
(checkpoint.store.reshard_stages) moves the weights.

  PYTHONPATH=src python examples/straggler_replan.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.simulator import TimeModel
from repro.runtime import rebalance_layers, replan_for_stragglers

p, m, g = 16, 64, 4  # 4 layers per stage
base = TimeModel(11.3, 11.25, 8.1, 0.38)  # paper 14.6B profile

print("-- comm-jitter straggler (recoverable by op re-planning alone) --")
slow_comm = TimeModel(11.3, 11.25, 8.1, 0.38 * 6)
sched, new_cost, old_cost = replan_for_stragglers(
    p, m, slow_comm, (1.0,) * p, m_limit=2.0 * p
)
print(f"6x comm latency: balanced plan {old_cost:.0f} -> re-planned {new_cost:.0f}")

print("-- uniformly slow stages (need layer rebalancing) --")
for slow_stage, factor in [(3, 1.2), (7, 1.5), (0, 2.0)]:
    scale = tuple(factor if s == slow_stage else 1.0 for s in range(p))
    layers, sched, new_cost, old_cost = rebalance_layers(
        p, m, base, scale, layers_per_stage=g, m_limit=2.0 * p
    )
    print(
        f"stage {slow_stage} {factor:.1f}x slow: cost {old_cost:.0f} -> "
        f"{new_cost:.0f} ({100*(old_cost-new_cost)/old_cost:.1f}% recovered), "
        f"layers={layers}"
    )
print("OK")
