"""Quickstart: build zero-bubble schedules, inspect them, run 3 train steps.

  PYTHONPATH=src python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.schedules import one_f_one_b, zb_h1, zb_h2, zb_v, search, compile_plan
from repro.core.simulator import TimeModel, simulate

# --- 1. schedules and bubbles (the paper's core object) ----------------- #
p, m = 4, 8
times = TimeModel(t_f=1.0, t_b=1.0, t_w=1.0, t_comm=0.0)
print("== 1F1B ==");  print(one_f_one_b(p, m).render())
print("== ZB-H2 (zero bubble, 2x memory) ==");  print(zb_h2(p, m).render())
print("== ZB-V (zero bubble, 1F1B memory) ==");  print(zb_v(p, m).render())
for sched, tm in [
    (one_f_one_b(p, m), TimeModel(1, 1, 1, 0, grouped_w=True)),
    (zb_h1(p, m), times), (zb_h2(p, m), times), (zb_v(p, m), times),
]:
    r = simulate(sched, tm)
    mem = sched.memory_profile(1.0 / sched.n_chunks, 0.5 / sched.n_chunks)
    print(f"{sched.name:8s} bubble_rate={r.bubble_rate:.4f} peak_mem={mem.max_peak:.1f} M_B")

# --- 2. automatic scheduling with profiled times (paper Sec. 3) --------- #
profiled = TimeModel(t_f=18.5, t_b=18.1, t_w=9.3, t_comm=0.6)
auto = search(p, m, profiled, m_limit=2.0 * p)
print(f"\nauto ZB-2p schedule: bubble_rate={auto.bubble_rate:.4f}")

# --- 3. three real pipelined train steps on CPU ------------------------- #
from repro.configs import get_reduced
from repro.core.executor import PipelineExecutor
from repro.models.lm import RunSpec, build_program, init_params, side_inputs

cfg = get_reduced("internlm2_1_8b")
spec = RunSpec(p=1, n_chunks=1, microbatch=2, seq_len=16, m=4)
sched = zb_h2(1, 4)
program = build_program(cfg, spec, sched.placement)
plan = compile_plan(sched)
grad_fn = PipelineExecutor(program, plan, pipe_axis="pipe").build_grad_fn()
stacked, shared = init_params(cfg, spec, sched.placement)
side = side_inputs(cfg, spec)
mesh = jax.make_mesh((1,), ("pipe",))
fn = jax.jit(shard_map(
    lambda st, sh, sd: grad_fn(
        tuple(jax.tree_util.tree_map(lambda a: a[0], x) for x in st), sh, sd
    )[2],
    mesh=mesh,
    in_specs=(tuple(jax.tree_util.tree_map(lambda _: P("pipe"), x) for x in stacked), P(), P()),
    out_specs=P(), check_rep=False,
))
print("\npipelined loss:", float(fn(stacked, shared, side)))
print("OK")
