"""AdamW rollback + post-validation semantics (paper Sec. 4, App. C/E)."""

import subprocess
import sys
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.optim import adamw, postval


def _params(seed, shapes=((4, 4), (8,))):
    ks = jax.random.split(jax.random.PRNGKey(seed), len(shapes))
    return {f"p{i}": jax.random.normal(k, s) for i, (k, s) in enumerate(zip(ks, shapes))}


CFG = adamw.AdamWConfig(lr=3e-3, b1=0.9, b2=0.95, eps=1e-8, weight_decay=0.1, grad_clip=1.0)


def test_step_then_rollback_is_identity():
    params = _params(0)
    grads = _params(1)
    state = adamw.init(params)
    # warm the state so t > 0 and moments are nontrivial
    for i in range(3):
        params, state = adamw.step(params, state, _params(10 + i), CFG)
    p1, s1 = adamw.step(params, state, grads, CFG)
    p0, s0 = adamw.rollback(p1, s1, grads, CFG)
    for k in params:
        np.testing.assert_allclose(p0[k], params[k], rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(s0.m[k], state.m[k], rtol=1e-5, atol=1e-6)
        np.testing.assert_allclose(s0.v[k], state.v[k], rtol=1e-5, atol=1e-6)
    assert int(s0.t) == int(state.t)


@given(seed=st.integers(0, 50), lr=st.sampled_from([1e-4, 1e-3, 1e-2]))
@settings(max_examples=20, deadline=None)
def test_property_rollback_inverse(seed, lr):
    cfg = adamw.AdamWConfig(lr=lr, weight_decay=0.05, grad_clip=None)
    params = _params(seed)
    grads = _params(seed + 1)
    state = adamw.init(params)
    params, state = adamw.step(params, state, _params(seed + 2), cfg)
    p1, s1 = adamw.step(params, state, grads, cfg)
    p0, s0 = adamw.rollback(p1, s1, grads, cfg)
    for k in params:
        np.testing.assert_allclose(p0[k], params[k], rtol=1e-4, atol=1e-5)


def _run_both(grads_scale, inject_nan, seed=0):
    """Run sync reference vs optimistic+validate; return both param trees."""
    params = _params(seed)
    grads = jax.tree_util.tree_map(lambda g: g * grads_scale, _params(seed + 1))
    if inject_nan:
        grads["p0"] = grads["p0"].at[0, 0].set(jnp.nan)
    state = adamw.init(params)

    # reference: blocking global decision
    ref_p, ref_s = postval.sync_step(params, state, grads, CFG)

    # post-validation: optimistic on partial stats, then validate with full.
    # Emulate a 2-stage pipe: this stage sees only half the sumsq initially.
    full = postval.local_stats(grads)
    partial = postval.GradStats(full.sumsq * 0.5, full.nonfinite)
    p1, s1, dec = postval.optimistic_step(params, state, grads, partial, CFG)
    p2, s2, amended = postval.validate_and_fix(p1, s1, grads, dec, full, CFG)
    return ref_p, p2, amended


def test_postval_matches_sync_no_clip():
    ref, got, amended = _run_both(grads_scale=0.05, inject_nan=False)
    assert not bool(amended)  # speculation was correct
    for k in ref:
        np.testing.assert_allclose(got[k], ref[k], rtol=1e-6, atol=1e-7)


def test_postval_matches_sync_clipped():
    ref, got, amended = _run_both(grads_scale=50.0, inject_nan=False)
    for k in ref:
        np.testing.assert_allclose(got[k], ref[k], rtol=1e-4, atol=1e-5)


def test_postval_matches_sync_nan_skip():
    ref, got, amended = _run_both(grads_scale=1.0, inject_nan=True)
    assert not bool(amended)  # partial already saw the NaN -> skipped, legit
    for k in ref:
        np.testing.assert_allclose(got[k], ref[k])


def test_postval_borderline_partial_ok_global_clip():
    """Partial norm under threshold, global norm over: rollback + redo."""
    params = _params(0)
    grads = jax.tree_util.tree_map(lambda g: g * 1.0, _params(1))
    state = adamw.init(params)
    full = postval.local_stats(grads)
    # force: partial passes, global clips
    partial = postval.GradStats(jnp.float32(0.25 * CFG.grad_clip**2), full.nonfinite)
    full_big = postval.GradStats(jnp.float32(9.0 * CFG.grad_clip**2), full.nonfinite)
    p1, s1, dec = postval.optimistic_step(params, state, grads, partial, CFG)
    p2, s2, amended = postval.validate_and_fix(p1, s1, grads, dec, full_big, CFG)
    assert bool(amended)
    want = postval.decide_global(full_big, CFG)
    ref_p, ref_s = adamw.step(params, state, grads, CFG, scale=want.scale)
    for k in params:
        np.testing.assert_allclose(p2[k], ref_p[k], rtol=1e-4, atol=1e-5)


_SPMD_PREFIX_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.optim import postval

mesh = jax.make_mesh((8,), ("pipe",))
x = jnp.arange(1.0, 9.0)  # per-stage sumsq
bad = jnp.zeros((8,), bool).at[5].set(True)

def body(sq, nf):
    stats = postval.GradStats(sq[0], nf[0])
    partial, full = postval.pipe_prefix_stats(stats, "pipe")
    return (partial.sumsq[None], partial.nonfinite[None],
            full.sumsq[None], full.nonfinite[None])

fn = shard_map(body, mesh=mesh, in_specs=(P("pipe"), P("pipe")),
               out_specs=(P("pipe"), P("pipe"), P("pipe"), P("pipe")))
psq, pbad, fsq, fbad = jax.jit(fn)(x, bad)
np.testing.assert_allclose(psq, np.cumsum(np.arange(1.0, 9.0)))
assert list(pbad) == [False]*5 + [True]*3
np.testing.assert_allclose(fsq, np.full(8, 36.0))
assert all(fbad)
print("OK")
"""


def test_pipe_prefix_stats_spmd():
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", _SPMD_PREFIX_SCRIPT],
        capture_output=True,
        text=True,
        env=env,
        timeout=300,
    )
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
