"""Controllable-memory subsystem tests: V-Min/V-Half, timeline, planner.

Acceptance (ISSUE 1): simulator-verified under T_F = T_B = T_W, t_comm = 0,
  * peak activation of v_min(p, m)  <= ceil(p*M_B/3) + 2*M_B,
  * peak activation of v_half(p, m) <= ceil(p*M_B/2) + 2*M_B,
  * bubble rate of both <= ZB-H1's at the same (p, m),
for p in {4, 6, 8}, m >= 2p; both pass IR validation and compile to
execution plans (SPMD loss parity is covered by tests/test_executor.py).
"""

import math

import numpy as np
import pytest

from repro.configs import get_config
from repro.core.memory import (
    ActivationByteModel,
    MemoryBudgetPlanner,
    memory_timeline,
)
from repro.core.schedules import (
    activation_peak,
    compile_plan,
    one_f_one_b,
    stable_v_schedule,
    v_flex,
    v_half,
    v_half_limit,
    v_min,
    v_min_limit,
    zb_h1,
    zb_v,
)
from repro.core.schedules.vflex import stable_pattern
from repro.core.simulator import TimeModel, simulate

UNIT = TimeModel(1.0, 1.0, 1.0, 0.0)


# --------------------------------------------------------------------- #
# V-Min / V-Half acceptance
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("p", [4, 6, 8])
@pytest.mark.parametrize("mfac", [2, 3])
def test_vmin_vhalf_bounds(p, mfac):
    m = mfac * p
    h1_rate = simulate(zb_h1(p, m), UNIT).bubble_rate
    for build, limit in ((v_min, v_min_limit(p)), (v_half, v_half_limit(p))):
        sched = build(p, m)
        sched.validate()  # IR validation: deadlock-free, complete
        assert activation_peak(sched, m_b=1.0) <= limit + 1e-9
        res = simulate(sched, UNIT)
        assert res.bubble_rate <= h1_rate + 1e-9, (
            f"{sched.name} p={p} m={m}: bubble rate {res.bubble_rate:.4f} "
            f"> ZB-H1 {h1_rate:.4f}"
        )
        plan = compile_plan(sched)  # compiles to the SPMD tick tables
        assert plan.total_ops == 6 * m * p // 2 * 2  # 3 kinds x m x 2 chunks


def test_vmin_below_vhalf_below_zbv_memory():
    p, m = 6, 12
    a_min = activation_peak(v_min(p, m))
    a_half = activation_peak(v_half(p, m))
    a_v = activation_peak(zb_v(p, m))
    assert a_min <= a_half <= a_v + 1e-9
    # the family point of V-Min: ~1/3 of 1F1B-parity activation memory
    assert a_min <= a_v * 2 / 3


def test_v_flex_respects_arbitrary_limits():
    p, m = 6, 12
    for limit in (4.0, 5.0, 6.0):
        sched = v_flex(p, m, limit, name=f"v@{limit}")
        assert activation_peak(sched) <= limit + 1e-9
        sched.validate()


def test_stable_pattern_structure():
    # residues mod 6 must be distinct per stage (no slot collisions), and the
    # repeated pattern must be a valid, deadlock-free schedule
    for kind, p in (("v-min", 4), ("v-min", 6), ("v-half", 4), ("v-half", 8)):
        rows = stable_pattern(p, kind)
        assert len(rows) == p
        for row in rows:
            assert len({t % 6 for t in row}) == 4
        sched = stable_v_schedule(p, 2 * p, kind)
        sched.validate()
        assert activation_peak(sched) <= (
            v_min_limit(p) if kind == "v-min" else v_half_limit(p)
        )


# --------------------------------------------------------------------- #
# time-resolved memory model
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("build", [one_f_one_b, zb_h1])
def test_timeline_brackets_op_profile(build):
    """The timeline peak equals the op-count profile up to the B-transient.

    The op-count profile applies B's delta (+M_W - M_B) atomically; the
    timeline keeps the activation until B *ends* while the W-context is
    already live, so per stage: profile <= timeline <= profile + M_B/C.
    """
    sched = build(4, 8)
    prof = sched.memory_profile(1.0, 0.5)
    tl = memory_timeline(sched, UNIT, m_b=1.0, m_w=0.5)
    C = sched.n_chunks
    for s in range(sched.p):
        assert tl.peak_total[s] >= prof.peak[s] - 1e-9
        assert tl.peak_total[s] <= prof.peak[s] + 1.0 / C + 1e-9


def test_timeline_activation_component():
    sched = v_min(6, 12)
    tl = memory_timeline(sched, UNIT, m_b=1.0, m_w=0.5)
    # activation component freed at B-end: within one chunk pass of the
    # op-count activation peak (which frees at B's position in the order)
    assert tl.max_peak_act <= activation_peak(sched) + 0.5 + 1e-9
    # global footprint is bounded by the sum of stage peaks
    t_mid = simulate(sched, UNIT).makespan / 2
    assert tl.global_footprint(t_mid) <= tl.peak_total.sum() + 1e-9


def test_byte_model_scaling():
    cfg = get_config("gpt3_1_5b")
    base = ActivationByteModel.from_config(cfg, microbatch=1, seq_len=2048, p=4)
    twice_mb = ActivationByteModel.from_config(cfg, microbatch=2, seq_len=2048, p=4)
    assert twice_mb.m_b_bytes == pytest.approx(2 * base.m_b_bytes)
    # beyond the dense-attention threshold (s > 2048) the chunked path
    # remats the scores, so sequence scaling is exactly linear there
    long1 = ActivationByteModel.from_config(cfg, microbatch=1, seq_len=4096, p=4)
    long2 = ActivationByteModel.from_config(cfg, microbatch=1, seq_len=8192, p=4)
    assert long2.m_b_bytes == pytest.approx(2 * long1.m_b_bytes)
    # tensor parallelism shards the stored activations
    tp2 = ActivationByteModel.from_config(cfg, 1, 2048, 4, tp_size=2)
    assert tp2.m_b_bytes == pytest.approx(base.m_b_bytes / 2)
    # W-context is a strict subset of the stored activations
    assert 0 < base.m_w_bytes < base.m_b_bytes


def test_byte_model_attn_scores_quadratic():
    """Dense short-seq attention stores the O(s^2) probs (ROADMAP item);
    chunked long-seq attention remats them.  Checked at two sequence
    lengths: the per-token delta is exactly n_heads * ds elements."""
    from repro.models.lm import ArchConfig

    cfg = ArchConfig(
        name="toy-dense-attn",
        family="dense",
        n_layers=4,
        d_model=64,
        n_heads=8,
        n_kv_heads=8,
        d_ff=256,
        vocab=128,
        block_pattern=(("attn", "mlp"),),
    )
    s1, s2 = 256, 512
    m1 = ActivationByteModel.from_config(cfg, microbatch=2, seq_len=s1, p=2)
    m2 = ActivationByteModel.from_config(cfg, microbatch=2, seq_len=s2, p=2)
    per_tok1 = m1.per_layer_act / m1.tokens
    per_tok2 = m2.per_layer_act / m2.tokens
    assert per_tok2 - per_tok1 == pytest.approx(
        cfg.n_heads * (s2 - s1) * m1.dtype_bytes
    )
    # super-linear (quadratic term) in the dense regime...
    assert m2.m_b_bytes > 2 * m1.m_b_bytes
    # ...and gone in the chunked regime: per-token attn bytes at 4096
    # drop back to the dense-free price
    m_long = ActivationByteModel.from_config(
        cfg, microbatch=2, seq_len=4096, p=2
    )
    per_tok_long = m_long.per_layer_act / m_long.tokens
    assert per_tok_long < per_tok1


# --------------------------------------------------------------------- #
# budget planner
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("arch", ["gpt3_1_5b", "gpt3_6_2b", "gemma2_2b"])
def test_planner_sweep_feasible_or_explicit(arch):
    cfg = get_config(arch)
    planner = MemoryBudgetPlanner(cfg, p=4, m=8, microbatch=1, seq_len=2048)
    totals = sorted(
        c.total_bytes for c in planner.candidates() if c.schedule is not None
    )
    lo, hi = 0.4 * totals[0], 1.2 * totals[-1]
    budgets = [lo + (hi - lo) * i / 5 for i in range(6)]  # 6-point sweep
    prev_cost = None
    feasible_seen = infeasible_seen = False
    for b in budgets:
        d = planner.plan(b)
        if d.feasible:
            feasible_seen = True
            assert d.chosen.schedule is not None
            assert d.chosen.total_bytes <= b + 1e-6
            # more memory never yields a slower plan
            if prev_cost is not None:
                assert d.chosen.cost <= prev_cost + 1e-9
            prev_cost = d.chosen.cost
        else:
            infeasible_seen = True
            assert d.chosen is None
            assert d.min_required_bytes > b  # explicit: what would fit
    assert feasible_seen and infeasible_seen


def test_planner_prefers_frugal_schedule_under_pressure():
    cfg = get_config("gpt3_1_5b")
    planner = MemoryBudgetPlanner(cfg, p=6, m=12, microbatch=1, seq_len=2048)
    by_name = {c.name: c for c in planner.candidates()}
    vmin = by_name["v-min"]
    # a budget that only admits the V-family's frugal end
    d = planner.plan(vmin.total_bytes * 1.01)
    assert d.feasible
    assert d.chosen.total_bytes <= vmin.total_bytes * 1.01 + 1e-6


def test_driver_replan_under_budget():
    from repro.runtime.driver import replan_under_budget

    cfg = get_config("gpt3_1_5b")
    byte_model = ActivationByteModel.from_config(cfg, 1, 2048, 4)
    # the runtime replan charges the same checked-in XLA-temp calibration
    # as launch-time planning (xla_temp_bytes=None default), so the budget
    # must cover it on top of the schedule bytes
    sched, decision = replan_under_budget(
        cfg, p=4, m=8, microbatch=1, seq_len=2048,
        budget_bytes=byte_model.m_b_bytes * 20 + byte_model.xla_temp_bytes,
    )
    assert decision.feasible
    sched.validate()
    with pytest.raises(RuntimeError, match="budget"):
        replan_under_budget(
            cfg, p=4, m=8, microbatch=1, seq_len=2048,
            budget_bytes=byte_model.m_b_bytes * 0.1,
        )
