"""F/B/W split correctness: auto_fbw and SequentialFBW vs jax.grad."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.passes import SequentialFBW, auto_fbw

jax.config.update("jax_enable_x64", False)


def _mlp_layer(p, x, side):
    h = jnp.tanh(x @ p["w1"] + p["b1"])
    return h @ p["w2"] + p["b2"]


def _mlp_params(key, d_in, d_hid, d_out):
    k1, k2 = jax.random.split(key)
    return {
        "w1": jax.random.normal(k1, (d_in, d_hid)) * 0.1,
        "b1": jnp.zeros((d_hid,)),
        "w2": jax.random.normal(k2, (d_hid, d_out)) * 0.1,
        "b2": jnp.zeros((d_out,)),
    }


def test_auto_fbw_matches_jax_grad():
    key = jax.random.PRNGKey(0)
    params = _mlp_params(key, 6, 16, 6)
    x = jax.random.normal(jax.random.PRNGKey(1), (4, 6))
    side = {}
    mod = auto_fbw(_mlp_layer, name="mlp")
    y, res = mod.fwd(params, x, side)
    dy = jax.random.normal(jax.random.PRNGKey(2), y.shape)
    dx, wctx = mod.bwd_x(params, res, dy, side)
    grads = mod.bwd_w(params, wctx, side)

    ref_grads, ref_dx = jax.vjp(lambda p, xx: _mlp_layer(p, xx, side), params, x)[
        1
    ](dy)
    np.testing.assert_allclose(dx, ref_dx, rtol=1e-6, atol=1e-6)
    for k in params:
        np.testing.assert_allclose(grads[k], ref_grads[k], rtol=1e-6, atol=1e-6)


def test_auto_fbw_param_leaves_not_stored():
    """Weights must not be duplicated into the residual buffers."""
    params = _mlp_params(jax.random.PRNGKey(0), 8, 32, 8)
    x = jnp.ones((2, 8))
    mod = auto_fbw(_mlp_layer, name="mlp")
    _, res = jax.jit(lambda p, xx: mod.fwd(p, xx, {}))(params, x)
    param_bytes = {v.shape for v in jax.tree_util.tree_leaves(params)}
    for leaf in res:
        assert leaf.shape not in {(8, 32), (32, 8)}, "weight stored in residuals"


def test_auto_fbw_side_inputs_reinjected():
    def f(p, x, side):
        return (x + side["bias"]) @ p["w"]

    params = {"w": jnp.eye(4)}
    side = {"bias": jnp.arange(4.0)}
    mod = auto_fbw(f)
    y, res = mod.fwd(params, jnp.ones((2, 4)), side)
    dx, wctx = mod.bwd_x(params, res, jnp.ones_like(y), side)
    grads = mod.bwd_w(params, wctx, side)
    np.testing.assert_allclose(dx, jnp.ones((2, 4)) @ params["w"].T)
    np.testing.assert_allclose(grads["w"], ((jnp.ones((2, 4)) + side["bias"]).T) @ jnp.ones((2, 4)))


def test_dce_split_flops():
    """B must not pay for the dW matmuls and vice versa (paper Table 1)."""
    d = 64
    params = {"w": jnp.ones((d, d))}

    def f(p, x, side):
        return x @ p["w"]

    mod = auto_fbw(f)
    x = jnp.ones((8, d))
    _, res = mod.fwd(params, x, {})
    dy = jnp.ones((8, d))

    def b_only(p, r, g):
        dx, _ = mod.bwd_x(p, r, g, {})
        return dx

    _, wctx = mod.bwd_x(params, res, dy, {})

    def w_only(p, w):
        return mod.bwd_w(p, w, {})

    def both(p, r, g):
        dx, w = mod.bwd_x(p, r, g, {})
        return dx, mod.bwd_w(p, w, {})

    def flops(fn, *args):
        cost = jax.jit(fn).lower(*args).compile().cost_analysis()
        if isinstance(cost, (list, tuple)):  # one dict per device program
            cost = cost[0]
        return cost["flops"]

    fb = flops(b_only, params, res, dy)
    fw = flops(w_only, params, wctx)
    fboth = flops(both, params, res, dy)
    matmul = 2 * 8 * d * d
    assert fb == pytest.approx(matmul, rel=0.05)
    assert fw == pytest.approx(matmul, rel=0.05)
    assert fboth == pytest.approx(2 * matmul, rel=0.05)


def test_sequential_fbw_matches_jax_grad():
    key = jax.random.PRNGKey(0)
    mods = [auto_fbw(_mlp_layer, name=f"mlp{i}") for i in range(3)]
    seq = SequentialFBW(mods)
    params = tuple(_mlp_params(jax.random.PRNGKey(i), 6, 12, 6) for i in range(3))
    x = jax.random.normal(key, (4, 6))
    y, res = seq.fwd(params, x, {})
    dy = jnp.ones_like(y)
    dx, wctx = seq.bwd_x(params, res, dy, {})
    grads = seq.bwd_w(params, wctx, {})

    def full(p, xx):
        out = xx
        for pi in p:
            out = _mlp_layer(pi, out, {})
        return out

    ref_grads, ref_dx = jax.vjp(full, params, x)[1](dy)
    np.testing.assert_allclose(dx, ref_dx, rtol=1e-5, atol=1e-6)
    for g, rg in zip(grads, ref_grads):
        for k in g:
            np.testing.assert_allclose(g[k], rg[k], rtol=1e-5, atol=1e-6)


def test_cross_jit_boundaries():
    """F, B, W traced in separate jit programs (as the executor does)."""
    params = _mlp_params(jax.random.PRNGKey(0), 4, 8, 4)
    x = jnp.ones((2, 4))
    mod = auto_fbw(_mlp_layer)
    mod.ensure_traced(params, x, {})
    y, res = jax.jit(lambda p, xx: mod.fwd(p, xx, {}))(params, x)
    dy = jnp.ones_like(y)
    dx, wctx = jax.jit(lambda p, r, g: mod.bwd_x(p, r, g, {}))(params, res, dy)
    grads = jax.jit(lambda p, w: mod.bwd_w(p, w, {}))(params, wctx)
    ref = jax.grad(lambda p: _mlp_layer(p, x, {}).sum())(params)
    for k in params:
        np.testing.assert_allclose(grads[k], ref[k], rtol=1e-5, atol=1e-6)


@given(
    b=st.integers(1, 4),
    d=st.sampled_from([3, 8]),
    depth=st.integers(1, 3),
    seed=st.integers(0, 10),
)
@settings(max_examples=15, deadline=None)
def test_property_split_equals_fused(b, d, depth, seed):
    mods = [auto_fbw(_mlp_layer, name=f"m{i}") for i in range(depth)]
    seq = SequentialFBW(mods)
    params = tuple(
        _mlp_params(jax.random.PRNGKey(seed + i), d, 2 * d, d) for i in range(depth)
    )
    x = jax.random.normal(jax.random.PRNGKey(seed + 99), (b, d))
    y, res = seq.fwd(params, x, {})
    dy = jax.random.normal(jax.random.PRNGKey(seed + 100), y.shape)
    dx, wctx = seq.bwd_x(params, res, dy, {})
    grads = seq.bwd_w(params, wctx, {})

    def full(p, xx):
        out = xx
        for pi in p:
            out = _mlp_layer(pi, out, {})
        return out

    ref_grads, ref_dx = jax.vjp(full, params, x)[1](dy)
    np.testing.assert_allclose(dx, ref_dx, rtol=2e-5, atol=1e-5)
    flat_g = jax.tree_util.tree_leaves(grads)
    flat_r = jax.tree_util.tree_leaves(ref_grads)
    for g, rg in zip(flat_g, flat_r):
        np.testing.assert_allclose(g, rg, rtol=2e-5, atol=1e-5)
