"""Per-architecture smoke tests: reduced config, one pipelined train step on
CPU (p=1 mesh), asserting finite loss and gradients of the right structure."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs import ARCH_IDS, PAPER_IDS, get_reduced
from repro.core.executor import PipelineExecutor
from repro.core.schedules import compile_plan, zb_h1
from repro.models.lm import RunSpec, build_program, init_params, side_inputs


def run_one_step(arch_id, p=1, m=2, b=2, s=16):
    cfg = get_reduced(arch_id)
    sched = zb_h1(p, m)
    plan = compile_plan(sched)
    spec = RunSpec(p=p, n_chunks=1, microbatch=b, seq_len=s, m=m)
    program = build_program(cfg, spec, sched.placement)
    stacked, shared = init_params(cfg, spec, sched.placement)
    side = side_inputs(cfg, spec)

    execu = PipelineExecutor(program, plan, pipe_axis="pipe")
    grad_fn = execu.build_grad_fn()
    mesh = jax.make_mesh((p,), ("pipe",))

    def body(stacked_local, shared, side):
        local = tuple(
            jax.tree_util.tree_map(lambda a: a[0], sp) for sp in stacked_local
        )
        grads, shared_grads, loss = grad_fn(local, shared, side)
        grads = tuple(
            jax.tree_util.tree_map(lambda a: a[None], g) for g in grads
        )
        return grads, shared_grads, loss

    spec_stacked = tuple(
        jax.tree_util.tree_map(lambda _: P("pipe"), sp) for sp in stacked
    )
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(spec_stacked, P(), P()),
        out_specs=(spec_stacked, P(), P()),
        check_rep=False,
    )
    grads, shared_grads, loss = jax.jit(fn)(stacked, shared, side)
    return cfg, grads, shared_grads, loss


@pytest.mark.parametrize("arch_id", ARCH_IDS + PAPER_IDS)
def test_arch_one_train_step(arch_id):
    cfg, grads, shared_grads, loss = run_one_step(arch_id)
    assert np.isfinite(float(loss)), f"{arch_id}: loss not finite"
    # loss should be ~log(vocab) for random init
    assert 0.1 * np.log(cfg.vocab) < float(loss) < 3.0 * np.log(cfg.vocab)
    ng = 0
    for g in jax.tree_util.tree_leaves(grads):
        assert np.all(np.isfinite(np.asarray(g))), f"{arch_id}: non-finite grad"
        ng += 1
    assert ng > 0
    for k, g in shared_grads.items():
        assert np.all(np.isfinite(np.asarray(g))), f"{arch_id}: shared {k}"
    # embedding must receive gradient signal
    assert float(jnp.abs(shared_grads["embed"]).max()) > 0
    assert float(jnp.abs(shared_grads["head"]).max()) > 0


def test_shape_cells_complete():
    from repro.configs.shapes import all_cells

    cells = list(all_cells())
    assert len(cells) == 40
    skips = [c for c in cells if c[3] is not None]
    # long_500k skipped for 8 full-attention archs; runs for ssm + hybrid
    assert len(skips) == 8
    for a, sid, cell, skip in skips:
        assert sid == "long_500k"
        assert a not in ("xlstm_350m", "recurrentgemma_9b")


def test_moe_scatter_matches_einsum_dispatch():
    """Scatter/gather MoE dispatch must equal the Mesh-TF einsum oracle
    (values and all gradients) -- see EXPERIMENTS.md Perf iteration 2."""
    import jax
    import jax.numpy as jnp
    from repro.models.modules import ShardCtx, apply_moe, init_moe

    cfg = dict(
        d_model=32, n_heads=4, n_kv_heads=4, d_ff=0, n_layers=2,
        head_dim=None, tp_size=1, moe_d_ff=16, n_experts=8, topk=2,
        n_shared_experts=1, capacity_factor=1.5,
    )
    ctx = ShardCtx()
    p = init_moe(jax.random.PRNGKey(0), cfg, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 12, 32))

    def run(dispatch):
        c = dict(cfg)
        c["moe_dispatch"] = dispatch
        f = lambda p, x: jnp.sum(apply_moe(p, x, c, ctx) ** 2)
        return jax.value_and_grad(f)(p, x)

    v1, g1 = run("einsum")
    v2, g2 = run("scatter")
    np.testing.assert_allclose(v1, v2, rtol=1e-5)
    for a, b in zip(
        jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)
    ):
        np.testing.assert_allclose(a, b, rtol=2e-4, atol=2e-5)
