"""Schedule IR, generators, and simulator tests (paper Secs. 2, 3, 5.3, 6)."""

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.schedules import (
    GreedyConfig,
    Placement,
    Schedule,
    Op,
    OpKind,
    compile_plan,
    gpipe,
    greedy_schedule,
    interleaved_1f1b,
    one_f_one_b,
    search,
    zb_h1,
    zb_h2,
    zb_v,
)
from repro.core.simulator import TimeModel, simulate

UNIT = TimeModel(1.0, 1.0, 1.0, 0.0)
UNIT_G = TimeModel(1.0, 1.0, 1.0, 0.0, grouped_w=True)


# --------------------------------------------------------------------- #
# Table 2: closed-form bubble sizes under T_F = T_B = T_W, T_comm = 0
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("p,m", [(2, 4), (4, 8), (4, 12), (8, 16), (8, 24)])
def test_table2_bubbles_unit_times(p, m):
    assert simulate(one_f_one_b(p, m), UNIT_G).bubble_size == pytest.approx(
        (p - 1) * 3.0
    )
    assert simulate(zb_h1(p, m), UNIT).bubble_size == pytest.approx(p - 1.0)
    assert simulate(zb_h2(p, m), UNIT).bubble_size == pytest.approx(0.0)


@pytest.mark.parametrize("p,m", [(4, 8), (4, 12), (8, 16), (8, 24)])
def test_table2_memory(p, m):
    m_b, m_w = 1.0, 0.5
    assert one_f_one_b(p, m).memory_profile(m_b, m_w).max_peak == pytest.approx(p)
    assert zb_h1(p, m).memory_profile(m_b, m_w).max_peak == pytest.approx(p)
    assert zb_h2(p, m).memory_profile(m_b, m_w).max_peak == pytest.approx(
        (2 * p - 1) * m_b
    )


def test_zb_h1_memory_per_stage_formula():
    # paper Sec 2.3: stage i (1-indexed) peak = (p-i+1) M_B + (i-1) M_W
    p, m, m_b, m_w = 4, 12, 1.0, 0.5
    prof = zb_h1(p, m).memory_profile(m_b, m_w)
    for s in range(p):
        i = s + 1
        assert prof.peak[s] == pytest.approx((p - i + 1) * m_b + (i - 1) * m_w)


# --------------------------------------------------------------------- #
# ZB-V: zero bubble at 1F1B-parity memory under unit times (Sec. 6)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("p,m", [(3, 6), (4, 8), (4, 12), (8, 16), (8, 24)])
def test_zbv_zero_bubble_unit_times(p, m):
    sched = zb_v(p, m)
    res = simulate(sched, UNIT)
    assert res.bubble_rate == pytest.approx(0.0, abs=1e-9)
    peak = sched.memory_profile(1.0 / 2, 0.5 / 2).max_peak
    assert peak <= p + 1e-9


def test_zbv_p2_near_zero():
    # p=2 is a degenerate V; a half-pass tail bubble remains (paper never
    # evaluates ZB-V below p=4).
    res = simulate(zb_v(2, 6), UNIT)
    assert res.bubble_rate < 0.03


# --------------------------------------------------------------------- #
# auto scheduler: zero bubble at 2p memory; <=H1 at p memory (Sec. 3)
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("p,m", [(2, 6), (4, 8), (4, 12), (8, 24)])
def test_auto_zb2p_zero_bubble_unit_times(p, m):
    res = search(p, m, UNIT, m_limit=2.0 * p)
    assert res.bubble_rate == pytest.approx(0.0, abs=1e-9)
    peak = res.schedule.memory_profile(1.0, 0.5).max_peak
    assert peak <= 2 * p + 1e-9


@pytest.mark.parametrize("p,m", [(4, 12), (8, 24)])
def test_auto_zb1p_at_most_h1(p, m):
    res = search(p, m, UNIT, m_limit=float(p))
    h1 = simulate(zb_h1(p, m), UNIT)
    assert res.cost <= h1.cost + 1e-9
    assert res.schedule.memory_profile(1.0, 0.5).max_peak <= p + 1e-9


# --------------------------------------------------------------------- #
# Table 5 reproduction: paper's profiled times -> paper's bubble rates
# --------------------------------------------------------------------- #
TABLE5 = [
    # p, m, TF, TB, TW, Tc, rates: (1f1b, zb-h1, zb-h2, zb-1p, zb-2p)
    (8, 24, 18.522, 18.086, 9.337, 0.601, (0.2431, 0.1585, 0.1083, 0.1585, 0.0433)),
    (8, 32, 18.513, 18.086, 9.331, 0.626, (0.1985, 0.1242, 0.0837, 0.1242, 0.0039)),
    (8, 64, 18.546, 18.097, 9.321, 0.762, (0.1240, 0.0674, 0.0444, 0.0674, 0.0026)),
    (8, 24, 29.718, 29.444, 19.927, 0.527, (0.2347, 0.1323, 0.0698, 0.1323, 0.0029)),
    (16, 48, 11.347, 11.248, 8.132, 0.377, (0.2552, 0.1397, 0.0672, 0.1397, 0.0066)),
    (32, 96, 10.419, 10.207, 7.715, 0.408, (0.2646, 0.1421, 0.0641, 0.1421, 0.0038)),
]


@pytest.mark.parametrize("p,m,tf,tb,tw,tc,rates", TABLE5)
def test_table5_reproduction(p, m, tf, tb, tw, tc, rates):
    tm = TimeModel(tf, tb, tw, tc)
    tmg = TimeModel(tf, tb, tw, tc, grouped_w=True)
    r_1f1b, r_h1, r_h2, r_1p, r_2p = rates
    assert simulate(one_f_one_b(p, m), tmg).bubble_rate == pytest.approx(
        r_1f1b, abs=2e-4
    )
    assert simulate(zb_h1(p, m), tm).bubble_rate == pytest.approx(r_h1, abs=2e-4)
    assert simulate(zb_h2(p, m), tm).bubble_rate == pytest.approx(r_h2, abs=2e-4)
    assert search(p, m, tm, m_limit=float(p)).bubble_rate == pytest.approx(
        r_1p, abs=2e-4
    )
    # heuristic-only ZB-2p: paper gets to polish with an ILP; allow 2e-3 abs
    assert search(p, m, tm, m_limit=2.0 * p).bubble_rate == pytest.approx(
        r_2p, abs=2e-3
    )


# --------------------------------------------------------------------- #
# Appendix H: m <= p still improves ~ (m+p-1) T_W - T_W worth of bubble
# --------------------------------------------------------------------- #
@pytest.mark.parametrize("p,m", [(8, 2), (8, 4), (8, 8)])
def test_small_m_speedup(p, m):
    tm = TimeModel(1.0, 1.0, 0.9, 0.0)
    tmg = TimeModel(1.0, 1.0, 0.9, 0.0, grouped_w=True)
    c_1f1b = simulate(one_f_one_b(p, m), tmg).cost
    c_zb = search(p, m, tm, m_limit=2.0 * p).cost
    # paper App. H: 1F1B ~ (m+p-1)(F+B+W); ZB ~ (m+p-1)(F+B) + W
    assert c_zb < c_1f1b
    expected_1f1b = (m + p - 1) * 2.9
    expected_zb = (m + p - 1) * 2.0 + 0.9
    assert c_1f1b == pytest.approx(expected_1f1b, rel=0.02)
    assert c_zb <= expected_zb * 1.05


# --------------------------------------------------------------------- #
# IR invariants (property tests)
# --------------------------------------------------------------------- #
@given(
    p=st.integers(2, 6),
    m=st.integers(2, 12),
    kind=st.sampled_from(["1f1b", "h1", "h2", "gpipe", "zbv"]),
)
@settings(max_examples=40, deadline=None)
def test_schedule_invariants(p, m, kind):
    sched = {
        "1f1b": lambda: one_f_one_b(p, m),
        "h1": lambda: zb_h1(p, m),
        "h2": lambda: zb_h2(p, m),
        "gpipe": lambda: gpipe(p, m),
        "zbv": lambda: zb_v(p, m),
    }[kind]()
    sched.validate()  # no deadlock
    ticks = sched.to_ticks()
    # every dependency strictly precedes its consumer
    for s in range(p):
        for op in sched.stage_ops[s]:
            for ds, dop in sched.dependencies(s, op):
                assert ticks[(ds, dop)] < ticks[(s, op)]
    # simulate agrees with tick count under unit durations, zero comm
    res = simulate(sched, TimeModel(1.0, 1.0, 1.0, 0.0))
    n_chunks = sched.n_chunks
    assert res.makespan * n_chunks == pytest.approx(sched.n_ticks())


@given(p=st.integers(2, 5), m=st.integers(2, 10), seed=st.integers(0, 3))
@settings(max_examples=30, deadline=None)
def test_greedy_respects_memory_limit(p, m, seed):
    limits = [float(p), 1.5 * p, 2.0 * p, p + 0.5]
    limit = limits[seed]
    cfg = GreedyConfig(m_limit=limit, m_b=1.0, m_w=0.5)
    sched = greedy_schedule(p, m, UNIT, cfg)
    peak = sched.memory_profile(1.0, 0.5).max_peak
    assert peak <= limit + 1e-9


def test_interleaved_requires_divisible():
    with pytest.raises(ValueError):
        interleaved_1f1b(4, 6, v=2)


def test_completeness_validation_rejects_missing_w():
    p, m = 2, 2
    ops = [
        [Op(OpKind.F, 0), Op(OpKind.F, 1), Op(OpKind.B, 0), Op(OpKind.B, 1)],
        [Op(OpKind.F, 0), Op(OpKind.F, 1), Op(OpKind.B, 0), Op(OpKind.B, 1)],
    ]
    with pytest.raises(ValueError):
        Schedule(p, m, ops)


# --------------------------------------------------------------------- #
# ExecutionPlan compilation
# --------------------------------------------------------------------- #
@pytest.mark.parametrize(
    "factory",
    [
        lambda: one_f_one_b(4, 8),
        lambda: zb_h1(4, 8),
        lambda: zb_h2(4, 8),
        lambda: zb_v(4, 8),
        lambda: interleaved_1f1b(4, 8, v=2),
    ],
)
def test_compile_plan_consistency(factory):
    sched = factory()
    plan = compile_plan(sched)
    assert plan.total_ops == 3 * sched.m * sched.n_chunks * sched.p
    # every non-idle op appears exactly once per (kind, mb, chunk, stage)
    seen = set()
    for s in range(plan.p):
        for t in range(plan.n_ticks):
            k = plan.op_kind[s, t]
            if k == int(OpKind.IDLE):
                continue
            key = (s, k, plan.op_mb[s, t], plan.op_chunk[s, t])
            assert key not in seen
            seen.add(key)
    # sends and receives must pair one-to-one per channel and tick
    for t in range(plan.n_ticks):
        for d in range(4):
            sends = int((plan.send_channel[:, t] == d).sum())
            recvs = int(plan.recv_valid[:, t, d].sum())
            assert sends == recvs


def test_straggler_rebalance_hook():
    """A 1.3x slower stage raises cost; re-searching with the profile helps."""
    p, m = 4, 12
    scale = tuple(1.3 if s == 2 else 1.0 for s in range(p))
    tm_slow = TimeModel(1.0, 1.0, 1.0, 0.0, stage_scale=scale)
    base = simulate(zb_h2(p, m), tm_slow)
    replanned = search(p, m, tm_slow, m_limit=2.0 * p)
    assert replanned.cost <= base.cost + 1e-9
