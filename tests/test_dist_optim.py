"""ZeRO-1 sharding + gradient compression (multi-pod substrate)."""

import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.optim import compress as C


def test_int8_compress_roundtrip_bounded():
    g = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 3.0
    q, scale = C.compress(g, "int8")
    back = C.decompress(q, scale, g.dtype)
    # absmax symmetric quantization: error <= scale/2 per element
    assert float(jnp.max(jnp.abs(back - g))) <= float(scale) * 0.5 + 1e-6


def test_error_feedback_is_residual():
    g = jax.random.normal(jax.random.PRNGKey(1), (64,))
    q, scale = C.compress(g, "int8")
    back = C.decompress(q, scale, g.dtype)
    e = C.ef_correct(g, back)
    np.testing.assert_allclose(np.asarray(back + e), np.asarray(g), rtol=1e-6)


_ZERO1_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.optim import adamw
from repro.optim.sharding import gather_params, scatter_grads, shard_leaf

mesh = jax.make_mesh((2,), ("pod",))
cfg = adamw.AdamWConfig(lr=1e-2, weight_decay=0.01, grad_clip=None)
params = {"w": jax.random.normal(jax.random.PRNGKey(0), (5, 3)),
          "b": jax.random.normal(jax.random.PRNGKey(1), (7,))}
g0 = {"w": jax.random.normal(jax.random.PRNGKey(2), (5, 3)),
      "b": jax.random.normal(jax.random.PRNGKey(3), (7,))}
g1 = jax.tree_util.tree_map(lambda g: g * 0.5, g0)
g_mean = jax.tree_util.tree_map(lambda a, b: (a + b) / 2, g0, g1)

# reference: replicated AdamW on the dp-mean grad
ref_p, _ = adamw.step(params, adamw.init(params), g_mean, cfg)

def body(params, g_stack):
    g_local = jax.tree_util.tree_map(lambda g: g[0], g_stack)
    shards = jax.tree_util.tree_map(lambda p: shard_leaf(p, "pod"), params)
    gsh = scatter_grads(g_local, "pod")
    st = adamw.init(shards)
    new_sh, _ = adamw.step(shards, st, gsh, cfg)
    return gather_params(new_sh, params, "pod")

fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P(), P("pod")), out_specs=P(),
                       check_rep=False))
g_stack = jax.tree_util.tree_map(lambda a, b: jnp.stack([a, b]), g0, g1)
got = fn(params, g_stack)
for k in params:
    np.testing.assert_allclose(np.asarray(got[k]), np.asarray(ref_p[k]),
                               rtol=1e-5, atol=1e-6)
print("OK zero1")
"""

_COMPRESS_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=2"
import jax, jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.optim.compress import compressed_psum

mesh = jax.make_mesh((2,), ("pod",))
g0 = jax.random.normal(jax.random.PRNGKey(0), (128,))
g1 = jax.random.normal(jax.random.PRNGKey(1), (128,))

def body(gs):
    g = gs[0]
    out, ef = compressed_psum({"g": g}, "pod", mode="int8")
    return out["g"], ef["g"]

fn = jax.jit(shard_map(body, mesh=mesh, in_specs=P("pod"),
                       out_specs=(P(), P("pod")), check_rep=False))
out, ef = fn(jnp.stack([g0, g1]))  # psum result replicated; ef per rank
exact = np.asarray(g0 + g1)
# int8 psum error bounded by sum of per-rank quantization steps
err = np.abs(np.asarray(out) - exact).max()
assert err < 0.2, err
print("OK compress", float(err))
"""


@pytest.mark.parametrize("script,tag", [(_ZERO1_SCRIPT, "zero1"), (_COMPRESS_SCRIPT, "compress")])
def test_spmd_dist_optim(script, tag):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, f"{tag}: {out.stderr[-1500:]}"
    assert "OK" in out.stdout
