"""Fault tolerance: checkpoint/restore exactness, mid-run failure recovery,
elastic re-shard, straggler re-planning."""

import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import store
from repro.configs import get_reduced
from repro.core.schedules import compile_plan, zb_h1
from repro.core.simulator import TimeModel, simulate
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import AxisBinding
from repro.launch.steps import TrainStepConfig, build_train_step
from repro.launch.train import side_from_batch
from repro.models.lm import RunSpec, init_params
from repro.optim import adamw
from repro.runtime import DriverConfig, TrainDriver, replan_for_stragglers


def _setup(ckpt_dir, p=1, m=4, b=2, s=16, steps_per_ckpt=3):
    cfg = get_reduced("internlm2_1_8b")
    sched = zb_h1(p, m)
    plan = compile_plan(sched)
    spec = RunSpec(p=p, n_chunks=1, microbatch=b, seq_len=s, m=m)
    mesh = jax.make_mesh((p,), ("data",))
    binding = AxisBinding(pipe="data", tp=None, dp=None)
    make, _ = build_train_step(
        cfg, spec, plan, sched.placement, mesh, binding, TrainStepConfig()
    )
    data = SyntheticLM(DataConfig(global_batch=m * b, seq_len=s, vocab=cfg.vocab))
    side0 = side_from_batch(data.batch_at(0), spec, cfg=cfg)
    step = make(side0)

    def init_state():
        stacked, shared = init_params(cfg, spec, sched.placement)
        z = lambda t: jax.tree_util.tree_map(
            lambda a: jnp.zeros(a.shape, jnp.float32), t
        )
        return dict(
            params=stacked,
            shared=shared,
            opt=adamw.AdamWState(jnp.zeros((), jnp.int32), z(stacked), z(stacked)),
            shared_opt=adamw.AdamWState(jnp.zeros((), jnp.int32), z(shared), z(shared)),
        )

    def step_fn(state, batch):
        side = side_from_batch(batch, spec, cfg=cfg)
        p_, sh, o, so, metrics = step(
            state["params"], state["shared"], state["opt"], state["shared_opt"], side
        )
        return dict(params=p_, shared=sh, opt=o, shared_opt=so), metrics

    driver = TrainDriver(
        DriverConfig(ckpt_dir=ckpt_dir, ckpt_every=steps_per_ckpt, max_retries=2),
        step_fn,
        init_state,
        data.batch_at,
    )
    return driver


def test_checkpoint_roundtrip():
    with tempfile.TemporaryDirectory() as d:
        tree = {
            "a": {"x": np.arange(6.0).reshape(2, 3), "y": np.ones((4,), np.int32)},
            "b": (np.zeros((2, 2)), np.full((3,), 7.0)),
        }
        store.save(d, 5, tree, meta={"p": 4})
        assert store.latest_step(d) == 5
        got, manifest = store.restore(d, 5, tree)
        assert manifest["step"] == 5
        for a, b in zip(
            jax.tree_util.tree_leaves(got), jax.tree_util.tree_leaves(tree)
        ):
            np.testing.assert_array_equal(a, b)


def test_failure_recovery_exact():
    """Crash at step 4, restore from ckpt at 3, final state must be bitwise
    equal to the uninterrupted run (deterministic data + optimizer)."""
    with tempfile.TemporaryDirectory() as d1, tempfile.TemporaryDirectory() as d2:
        clean = _setup(d1)
        state_clean, metrics_clean = clean.run(6)

        crashed = {"done": False}

        def fail_hook(step):
            if step == 4 and not crashed["done"]:
                crashed["done"] = True
                raise RuntimeError("simulated node failure")

        faulty = _setup(d2)
        state_faulty, metrics_faulty = faulty.run(6, fail_hook=fail_hook)
        assert crashed["done"]

        for a, b in zip(
            jax.tree_util.tree_leaves(state_clean["params"]),
            jax.tree_util.tree_leaves(state_faulty["params"]),
        ):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
        # same final loss trajectory after the restore point
        l_clean = {s: float(m["loss"]) for s, m in metrics_clean}
        l_faulty = {s: float(m["loss"]) for s, m in metrics_faulty}
        assert l_clean[5] == l_faulty[5]


def test_elastic_reshard():
    leaf = np.arange(4 * 6 * 5.0).reshape(4, 6, 5)  # (p=4, g=6, d)
    out = store.reshard_stages({"w": leaf}, p_old=4, p_new=2)
    assert out["w"].shape == (2, 12, 5)
    np.testing.assert_array_equal(out["w"].reshape(-1), leaf.reshape(-1))
    back = store.reshard_stages(out, p_old=2, p_new=4)
    np.testing.assert_array_equal(back["w"], leaf)
    with pytest.raises(ValueError):
        store.reshard_stages({"w": leaf}, p_old=4, p_new=7)


def test_straggler_replanning_reduces_cost():
    """A 1.4x slow stage: re-searching the schedule for the observed profile
    must beat the balanced-profile schedule run on the degraded hardware."""
    p, m = 8, 24
    base = TimeModel(18.5, 18.1, 9.3, 0.6)
    scale = tuple(1.4 if s == 3 else 1.0 for s in range(p))
    sched, replanned_cost, base_cost = replan_for_stragglers(
        p, m, base, scale, m_limit=2.0 * p
    )
    assert replanned_cost <= base_cost + 1e-9
    # and the replanned schedule is still a valid ZB schedule
    sched.validate()
