"""Serving smoke tests: prefill then decode per arch (reduced, p=1, CPU).

Also checks prefill->decode consistency for the dense family: decoding token
t+1 with a prefilled cache must match the train-path forward logits at the
same position.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.configs import ARCH_IDS, get_reduced
from repro.core.infer_executor import InferExecutor, compile_infer_plan
from repro.core.schedules.ir import Placement
from repro.models.lm import RunSpec, init_params, side_inputs
from repro.models.serve import build_serve_program


def _stack_caches(cache_init, m, b, S):
    one = cache_init(b, S)
    return jax.tree_util.tree_map(
        lambda a: jnp.zeros((m,) + a.shape, a.dtype), one
    )


def run_serve(arch_id, mode, p=1, m=2, b=2, s=16, S=24):
    cfg = get_reduced(arch_id)
    placement = Placement.linear(p)
    spec = RunSpec(p=p, n_chunks=1, microbatch=b, seq_len=s, m=m)
    program, cache_init, _ = build_serve_program(cfg, spec, placement, mode)
    plan = compile_infer_plan(placement, m)
    stacked, shared = init_params(cfg, spec, placement)
    if mode == "decode":
        side = {
            "tokens": jax.random.randint(jax.random.PRNGKey(0), (m, b, 1), 0, cfg.vocab),
            "positions": jnp.broadcast_to(jnp.arange(1), (m, 1)),
        }
        pos = S - 1
    else:
        side = side_inputs(cfg, spec)
        pos = 0
    caches = [_stack_caches(cache_init, m, b, S)]

    execu = InferExecutor(program, plan, pipe_axis="pipe")
    step = execu.build_step_fn()
    mesh = jax.make_mesh((p,), ("pipe",))

    def body(stacked_local, shared, side, caches):
        local = tuple(
            jax.tree_util.tree_map(lambda a: a[0], sp) for sp in stacked_local
        )
        out, newc = step(local, shared, side, caches, pos)
        return out

    spec_stacked = tuple(
        jax.tree_util.tree_map(lambda _: P("pipe"), sp) for sp in stacked
    )
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(spec_stacked, P(), P(), P()),
        out_specs=P(),
        check_rep=False,
    )
    out = jax.jit(fn)(stacked, shared, side, caches)
    return cfg, out


@pytest.mark.parametrize("arch_id", ARCH_IDS)
def test_decode_step(arch_id):
    cfg, out = run_serve(arch_id, "decode")
    assert out.shape[0] == 2  # m groups
    assert np.all(np.isfinite(np.asarray(out, np.float32))), arch_id


@pytest.mark.parametrize(
    "arch_id", ["internlm2_1_8b", "xlstm_350m", "recurrentgemma_9b", "gemma2_2b"]
)
def test_prefill_step(arch_id):
    cfg, out = run_serve(arch_id, "prefill")
    assert np.all(np.isfinite(np.asarray(out, np.float32))), arch_id


def test_prefill_then_decode_consistency():
    """Dense arch: prefill caches then decode pos s must equal the train
    forward's next-token logits."""
    from repro.core.executor import PipelineExecutor
    from repro.core.schedules import compile_plan, one_f_one_b
    from repro.models.lm import build_program
    from repro.models.modules import ShardCtx, rmsnorm
    from repro.models.lm import make_chunk_fn, _embed_lookup

    arch_id = "internlm2_1_8b"
    cfg = get_reduced(arch_id)
    p, m, b, s = 1, 1, 2, 8
    S = s + 1
    placement = Placement.linear(p)
    spec = RunSpec(p=p, n_chunks=1, microbatch=b, seq_len=s, m=m)
    stacked, shared = init_params(cfg, spec, placement)
    params0 = jax.tree_util.tree_map(lambda a: a[0], stacked[0])
    ctx = ShardCtx()

    key = jax.random.PRNGKey(3)
    tokens = jax.random.randint(key, (b, s + 1), 0, cfg.vocab)

    # reference: full forward over s+1 tokens, logits at position s
    chunk_fn, _, _ = make_chunk_fn(cfg, p, 1, ctx)
    x = _embed_lookup(shared, tokens, cfg, ctx)
    side_full = {"positions": jnp.arange(s + 1)}
    y = chunk_fn(params0, x, side_full)
    yn = rmsnorm(shared["final_ln"], y[:, -1:])
    ref_logits = (yn @ shared["head"])[:, 0]

    # prefill on first s tokens
    prog_pre, cache_init, _ = build_serve_program(cfg, spec, placement, "prefill")
    from repro.models.serve import make_serve_chunk

    pre_chunk, _, _ = make_serve_chunk(cfg, spec, "prefill")
    cache = cache_init(b, S)
    side_pre = {"positions": jnp.arange(s)}
    xp = _embed_lookup(shared, tokens[:, :s], cfg, ctx)
    _, cache = pre_chunk(params0, xp, side_pre, cache, 0)

    # decode token s
    dec_chunk, _, _ = make_serve_chunk(cfg, spec, "decode")
    xd = _embed_lookup(shared, tokens[:, s:], cfg, ctx)
    yd, _ = dec_chunk(params0, xd, {}, cache, s)
    yn = rmsnorm(shared["final_ln"], yd)
    dec_logits = (yn @ shared["head"])[:, 0]

    np.testing.assert_allclose(
        np.asarray(dec_logits), np.asarray(ref_logits), rtol=2e-4, atol=2e-4
    )


def test_context_parallel_prefill_parity():
    """CP prefill (seq-sharded, full weights) == dense reference (Perf iter3)."""
    import os
    import subprocess
    import sys

    script = r'''
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map
from repro.models.modules import ShardCtx, apply_layer, init_layer
from repro.models.serve import prefill_block_cp

cfg = dict(d_model=32, n_heads=4, n_kv_heads=2, d_ff=64, n_layers=2, head_dim=None, tp_size=1)
ctx0 = ShardCtx()
key = jax.random.PRNGKey(0)
pa = init_layer("attn", key, cfg, ctx0, jnp.float32)
pm = init_layer("mlp", jax.random.fold_in(key, 1), cfg, ctx0, jnp.float32)
b, s = 2, 16
x = jax.random.normal(jax.random.PRNGKey(2), (b, s, 32))
pos = jnp.arange(s)
ref = apply_layer("mlp", pm, apply_layer("attn", pa, x, pos, cfg, ctx0), pos, cfg, ctx0)
mesh = jax.make_mesh((4,), ("model",))
ctx = ShardCtx(tp_axis="model", tp_size=4)
def body(pa, pm, xl):
    off = jax.lax.axis_index("model") * (s // 4)
    y, _ = prefill_block_cp("attn", pa, xl, cfg, ctx, off, s)
    y, _ = prefill_block_cp("mlp", pm, y, cfg, ctx, off, s)
    return y
fn = jax.jit(shard_map(body, mesh=mesh, in_specs=(P(), P(), P(None, "model")),
                       out_specs=P(None, "model"), check_rep=False))
np.testing.assert_allclose(np.asarray(fn(pa, pm, x)), np.asarray(ref), rtol=2e-5, atol=2e-5)
print("OK")
'''
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, "-c", script],
        capture_output=True,
        text=True,
        env=env,
        timeout=600,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
