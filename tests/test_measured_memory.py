"""Measured-vs-modeled executor memory (ISSUE 2 acceptance).

The tick executor now implements the paper's accounting in real buffers:
residual slots are live [F, B] (B's true split-VJP emits the compact M_W
context and frees the activation), W-contexts [B, W], and residual/W-context
pools are shared across chunks.  These tests cross-check the *measured*
executor allocation (`PipelineExecutor.buffer_bytes` /
`core.memory.measured_timeline` -- real pytree leaf bytes x the plan's
interval analysis) against the analytic `ActivationByteModel` on the tick
timebase, for 1F1B / ZB-H1 / ZB-V / V-Min / V-Half on tiny configs, and
assert the V-Min frugality claims PR 1 could only simulate:

  * measured peak activation bytes match the model within 10%;
  * V-Min's measured activation bytes = (ceil(p/3) + 2) * M_B, i.e.
    (ceil(p/3)+2)/p of ZB-H1's p * M_B -- 0.625x at p=8 (the +2*M_B term is
    the V ramp transient; it is why the asymptotic 1/3 reads as 5/8 at
    p=8);
  * net of that transient, the steady-state slope is <= 0.40x at p=8 --
    the paper's ~1/3 claim in measured bytes.

No devices are needed: buffer sizing is abstract (`jax.eval_shape`), and the
slot pools the executor allocates *are* its peak resident set (greedy
interval coloring is optimal on interval graphs).  The tier-2 CI job runs
this module under an 8-fake-device mesh next to the SPMD parity tests.
"""

import math

import jax
import numpy as np
import pytest

from repro.core.executor import PipelineExecutor
from repro.core.memory import (
    ActivationByteModel,
    measured_timeline,
    measured_unit_bytes,
    memory_timeline,
)
from repro.core.schedules import (
    compile_plan,
    one_f_one_b,
    v_half,
    v_min,
    v_min_limit,
    zb_h1,
    zb_v,
)
from repro.models.lm import ArchConfig, RunSpec, build_program, init_params, side_inputs

# n_layers divisible by p * n_chunks for p in {4, 8}: no padded blocks, so
# 1-chunk and 2-chunk layouts carry identical real bytes per stage.
TINY_DENSE = ArchConfig(
    name="tiny_dense", family="dense", n_layers=16, d_model=16, n_heads=2,
    n_kv_heads=2, d_ff=32, vocab=64,
)
TINY_GQA = ArchConfig(
    name="tiny_gqa", family="dense", n_layers=16, d_model=16, n_heads=4,
    n_kv_heads=2, d_ff=48, vocab=64, head_dim=4,
)
TINY_RECURRENT = ArchConfig(
    name="tiny_rec", family="hybrid", n_layers=16, d_model=16, n_heads=2,
    n_kv_heads=2, d_ff=32, vocab=64,
    block_pattern=(("rglru", "mlp"),),
)

SCHEDULES = {
    "1f1b": (one_f_one_b, 1),
    "zb-h1": (zb_h1, 1),
    "zb-v": (zb_v, 2),
    "v-min": (v_min, 2),
    "v-half": (v_half, 2),
}


def build_measured(cfg, p, m, sched_name):
    build, n_chunks = SCHEDULES[sched_name]
    spec = RunSpec(p=p, n_chunks=n_chunks, microbatch=2, seq_len=8, m=m)
    sched = build(p, m)
    plan = compile_plan(sched)
    prog = build_program(cfg, spec, sched.placement)
    exe = PipelineExecutor(prog, plan, pipe_axis="pipe")
    stacked, shared = init_params(cfg, spec, sched.placement)
    sp = tuple(
        jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), s
        )
        for s in stacked
    )
    side = side_inputs(cfg, spec)
    mt = measured_timeline(exe, sp, shared, side)
    return sched, exe, mt, (sp, shared, side)


GRID = [(4, 8), (8, 16)]


@pytest.mark.parametrize("cfg", [TINY_DENSE, TINY_GQA, TINY_RECURRENT],
                         ids=lambda c: c.name)
@pytest.mark.parametrize("p,m", GRID)
@pytest.mark.parametrize("sched_name", list(SCHEDULES))
def test_measured_matches_model_within_10pct(cfg, p, m, sched_name):
    sched, exe, mt, _ = build_measured(cfg, p, m, sched_name)
    m_b, m_w = mt.unit_bytes()
    model = ActivationByteModel.from_measured(m_b, m_w)
    act_model, wctx_model, _ = model.schedule_bytes(sched, tick_times=True)
    assert mt.alloc_act == pytest.approx(act_model, rel=0.10), (
        f"{sched_name}: measured activation bytes {mt.alloc_act:.0f} vs "
        f"modeled {act_model:.0f}"
    )
    assert mt.alloc_wctx == pytest.approx(wctx_model, rel=0.10), (
        f"{sched_name}: measured W-context bytes {mt.alloc_wctx:.0f} vs "
        f"modeled {wctx_model:.0f}"
    )
    # static pool allocation == peak of the per-tick live timeline (the
    # executor's slot pools are sized by optimal interval coloring)
    assert mt.max_peak_act == pytest.approx(mt.alloc_act, rel=1e-6)
    # the sink (head + loss) buffers are real and accounted
    assert mt.alloc_sink > 0


@pytest.mark.parametrize("p,m", GRID)
def test_vmin_measured_frugality_vs_zbh1(p, m):
    """The V-Min/ZB-H1 ratio in *measured* bytes (PR 1's simulated claim)."""
    _, _, mt_h1, _ = build_measured(TINY_DENSE, p, m, "zb-h1")
    _, _, mt_vm, _ = build_measured(TINY_DENSE, p, m, "v-min")
    m_b, _ = mt_vm.unit_bytes()
    # units agree between the 1-chunk and 2-chunk layouts (no padding)
    assert mt_h1.unit_bytes()[0] == pytest.approx(m_b, rel=1e-6)

    # ZB-H1 keeps p in-flight microbatches at stage 0: exactly p * M_B.
    assert mt_h1.alloc_act == pytest.approx(p * m_b, rel=1e-6)
    # V-Min realizes its analytic budget ceil(p/3) + 2 in real buffers.
    limit = v_min_limit(p)
    assert mt_vm.alloc_act <= limit * m_b * (1 + 1e-6)
    ratio = mt_vm.alloc_act / mt_h1.alloc_act
    assert ratio <= limit / p + 1e-6

    # steady-state slope, net of the 2*M_B V-ramp transient: the ~1/3 claim.
    steady = (mt_vm.alloc_act - 2 * m_b) / mt_h1.alloc_act
    assert steady <= math.ceil(p / 3) / p + 1e-6
    if p >= 8:
        assert ratio <= 0.70  # 0.625 at p=8; seed executor measured 1.5x
        assert steady <= 0.40  # the paper's 1/3, measured


@pytest.mark.parametrize("p,m", GRID)
def test_measured_family_ordering(p, m):
    """V-Min <= V-Half <= ZB-V in measured activation bytes."""
    acts = {}
    for name in ("v-min", "v-half", "zb-v"):
        _, _, mt, _ = build_measured(TINY_DENSE, p, m, name)
        acts[name] = mt.alloc_act
    assert acts["v-min"] <= acts["v-half"] * (1 + 1e-9)
    assert acts["v-half"] <= acts["zb-v"] * (1 + 1e-9)


def _per_block_wctx_bytes(cfg, compact):
    """Measured per-block B->W context bytes through the real executor
    buffer sizing (ChunkFBW + eval_shape), per block of chunk 0."""
    p, m = 2, 4
    spec = RunSpec(p=p, n_chunks=1, microbatch=2, seq_len=16, m=m)
    sched = zb_h1(p, m)
    plan = compile_plan(sched)
    prog = build_program(cfg, spec, sched.placement, compact=compact)
    exe = PipelineExecutor(prog, plan, pipe_axis="pipe")
    stacked, shared = jax.eval_shape(
        lambda: init_params(cfg, spec, sched.placement)
    )
    sp = tuple(
        jax.tree_util.tree_map(
            lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), s
        )
        for s in stacked
    )
    side = jax.eval_shape(lambda: side_inputs(cfg, spec))
    bb = exe.buffer_bytes(sp, shared, side)
    return prog.chunks[0].block_kinds, list(bb["wctx_block_bytes"][0])


RECURRENT_KINDS = {"slstm", "mlstm", "rglru"}


@pytest.mark.parametrize("arch", ["xlstm_350m", "recurrentgemma_9b"])
def test_recurrent_wctx_shrinks_30pct_measured(arch):
    """ISSUE 4 acceptance: for the xlstm-350m and recurrentgemma-9b tiny
    variants, measured per-block W-context bytes of every *recurrent*
    block shrink >= 30% under the compact split vs. the pre-split
    (whole-scan-in-B) baseline."""
    import importlib

    cfg = importlib.import_module(f"repro.configs.{arch}").reduced()
    kinds, base = _per_block_wctx_bytes(cfg, compact=False)
    kinds2, compact = _per_block_wctx_bytes(cfg, compact=True)
    assert kinds == kinds2
    checked = 0
    for bk, b0, b1 in zip(kinds, base, compact):
        if not (set(bk) & RECURRENT_KINDS):
            continue
        checked += 1
        assert b1 <= 0.70 * b0, (
            f"{arch} block {bk}: compact wctx {b1}B > 70% of "
            f"whole-scan-in-B baseline {b0}B"
        )
    assert checked > 0  # the reduced configs keep their recurrent blocks


def test_planner_sees_smaller_recurrent_m_w():
    """plan()'s itemized breakdown reflects the smaller M_W: measured
    fidelity on the compact program prices wctx below the frontier
    baseline program, and the analytic model agrees directionally."""
    from repro.core.planner import HBMPlanner

    cfg = TINY_RECURRENT
    p, m = 4, 8

    def factory(compact):
        def make(n_chunks):
            spec = RunSpec(p=p, n_chunks=n_chunks, microbatch=2, seq_len=8, m=m)
            pl = (zb_v(p, m) if n_chunks == 2 else one_f_one_b(p, m)).placement
            prog = build_program(cfg, spec, pl, compact=compact)
            stacked, shared = jax.eval_shape(
                lambda: init_params(cfg, spec, pl)
            )
            sp = tuple(
                jax.tree_util.tree_map(
                    lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), s
                )
                for s in stacked
            )
            return prog, sp, shared, jax.eval_shape(
                lambda: side_inputs(cfg, spec)
            )

        return make

    wctx = {}
    for compact in (False, True):
        planner = HBMPlanner(
            cfg, p=p, m=m, microbatch=2, seq_len=8,
            measured=True, program_factory=factory(compact),
        )
        report = planner.plan(float("inf"))
        assert report.feasible
        by_name = {c.name: c for c in report.plans if c.schedule is not None}
        wctx[compact] = by_name["zb-h1"].breakdown.wctx
    assert wctx[True] < wctx[False]

    analytic_compact = ActivationByteModel.from_config(
        cfg, 2, 8, p, compact=True
    )
    analytic_frontier = ActivationByteModel.from_config(
        cfg, 2, 8, p, compact=False
    )
    assert analytic_compact.m_w_bytes < analytic_frontier.m_w_bytes
    assert analytic_compact.m_b_bytes == analytic_frontier.m_b_bytes


def test_wctx_is_smaller_than_full_retention():
    """M_W < M_B: the split's W-context beats keeping residuals F->W.

    The seed executor retained the full residual set until W; the per-slot
    W-context the true split emits must be strictly smaller than the
    residual slot it replaces.
    """
    _, _, mt, _ = build_measured(TINY_DENSE, 4, 8, "zb-h1")
    m_b, m_w = mt.unit_bytes()
    assert 0 < m_w < m_b


def test_analytic_per_kind_table_in_range():
    """The config-level analytic table stays within 5x of measured units
    (it is a per-kind heuristic; calibration is ROADMAP work)."""
    for cfg in (TINY_DENSE, TINY_RECURRENT):
        _, exe, mt, (sp, shared, side) = build_measured(cfg, 4, 8, "zb-h1")
        m_b_meas, _ = measured_unit_bytes(exe, sp, shared, side)
        analytic = ActivationByteModel.from_config(
            cfg, microbatch=2, seq_len=8, p=4, n_chunks=1
        )
        assert 0.2 < analytic.m_b_bytes / m_b_meas < 5.0


def test_driver_replan_validates_measured_bytes():
    """replan_under_budget(program_factory=...) enforces the budget on real
    executor buffers, not just the analytic model."""
    from repro.core.memory import MemoryBudgetPlanner
    from repro.runtime.driver import replan_under_budget

    cfg = TINY_DENSE
    p, m = 4, 8

    def factory(n_chunks):
        spec = RunSpec(p=p, n_chunks=n_chunks, microbatch=2, seq_len=8, m=m)
        pl = (zb_v(p, m) if n_chunks == 2 else one_f_one_b(p, m)).placement
        prog = build_program(cfg, spec, pl)
        stacked, shared = init_params(cfg, spec, pl)
        sp = tuple(
            jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), s
            )
            for s in stacked
        )
        return prog, sp, shared, side_inputs(cfg, spec)

    _, _, mt_ref, _ = build_measured(cfg, p, m, "zb-h1")

    # generous budget: passes both the model and the measured validation
    sched_ok, decision = replan_under_budget(
        cfg, p=p, m=m, microbatch=2, seq_len=8,
        budget_bytes=mt_ref.alloc_total * 50,
        program_factory=factory,
    )
    assert decision.feasible
    sched_ok.validate()

    # a budget below the provable measured floor -- fixed params/optimizer
    # state plus half a measured M_B unit (every schedule keeps at least
    # one full-stage residual in flight at peak) -- must be rejected on
    # measured bytes, whatever limit the planner's budget-implied searches
    # refine down to.  (The planner may legitimately *satisfy* budgets the
    # static family exceeds, by searching frugal v_flex/auto plans; the
    # hard floor is what cannot be planned around.)
    planner = MemoryBudgetPlanner(
        cfg, p=p, m=m, microbatch=2, seq_len=8,
        measured=True, program_factory=factory,
    )
    m_b_meas, _ = mt_ref.unit_bytes()
    fixed = min(
        sum(planner.hbm.fixed_bytes(1)), sum(planner.hbm.fixed_bytes(2))
    )
    floor = fixed + 0.5 * m_b_meas
    with pytest.raises(RuntimeError, match="measured"):
        replan_under_budget(
            cfg, p=p, m=m, microbatch=2, seq_len=8,
            budget_bytes=floor,
            program_factory=factory,
        )


def test_measured_timeline_consistency():
    """Timeline series are non-negative, peak where the pools say, and the
    tick-timebase model agrees with the event model up to the B-transient."""
    sched, exe, mt, _ = build_measured(TINY_DENSE, 4, 8, "v-min")
    assert (mt.act_bytes >= 0).all() and (mt.wctx_bytes >= 0).all()
    m_b, m_w = mt.unit_bytes()
    tick = memory_timeline(sched, m_b=m_b, m_w=m_w, tick_times=True)
    event = memory_timeline(sched, m_b=m_b, m_w=m_w)
    # both models bracket the measured peak within one chunk pass
    for tl in (tick, event):
        assert tl.peak_act.max() == pytest.approx(
            mt.max_peak_act, abs=m_b / sched.n_chunks + 1e-6
        )
