"""Parity of sequence-sharded pipeline channels (pipe=2 x tp=2 mesh).

Each TP rank sends only its seq slice over the pipe axis; consumers
all-gather over TP.  Loss/grads must equal the dense-channel reference
exactly.
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.executor import PipelineExecutor, PipelineProgram
from repro.core.passes import auto_fbw
from repro.core.schedules import compile_plan, zb_h2

jax.config.update("jax_enable_x64", True)
DT = jnp.float64
P_, M_, B_, S_, D_ = 2, 4, 2, 8, 4  # seq S_ divides tp=2


def layer_fn(p, x, side):
    return jnp.tanh(x @ p["w"])


def sink_fn(shared, y, side):
    return jnp.sum((y @ shared["w_out"] - side["target"]) ** 2) / M_


def src_fwd(shared, side_mb):
    return side_mb["x0"] @ shared["w_in"]


def src_bwd_w(shared, side_mb, dx):
    return {
        "w_in": jnp.einsum("bsd,bsh->dh", side_mb["x0"], dx),
        "w_out": jnp.zeros_like(shared["w_out"]),
    }


def run(shard_channels):
    sched = zb_h2(P_, M_)
    plan = compile_plan(sched)
    keys = jax.random.split(jax.random.PRNGKey(0), P_ + 3)
    stage_params = [
        {"w": (jax.random.normal(keys[s], (D_, D_)) * 0.4).astype(DT)}
        for s in range(P_)
    ]
    shared = {
        "w_in": (jax.random.normal(keys[-1], (D_, D_)) * 0.4).astype(DT),
        "w_out": (jax.random.normal(keys[-2], (D_, D_)) * 0.4).astype(DT),
    }
    side = {
        "x0": jax.random.normal(keys[-3], (M_, B_, S_, D_)).astype(DT),
        "target": jax.random.normal(jax.random.PRNGKey(9), (M_, B_, S_, D_)).astype(DT),
    }
    program = PipelineProgram(
        chunks=[auto_fbw(layer_fn, name="chunk0")],
        src_fwd=src_fwd,
        src_bwd_w=src_bwd_w,
        sink=auto_fbw(sink_fn, name="sink"),
        act_shape=(B_, S_, D_),
        act_dtype=DT,
    )
    execu = PipelineExecutor(
        program,
        plan,
        pipe_axis="pipe",
        tp_axis="model",
        shard_channels=shard_channels,
    )
    grad_fn = execu.build_grad_fn()
    mesh = jax.make_mesh((P_, 2), ("pipe", "model"))
    stacked = jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *stage_params)

    def body(st, sh, sd):
        local = jax.tree_util.tree_map(lambda a: a[0], st)
        grads, sgrads, loss = grad_fn((local,), sh, sd)
        return (
            jax.tree_util.tree_map(lambda a: a[None], grads[0]),
            sgrads,
            loss,
        )

    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(P("pipe"), P(), P()),
        out_specs=(P("pipe"), P(), P()),
        check_rep=False,
    )
    return jax.jit(fn)(stacked, shared, side)


def main():
    g1, s1, l1 = run(shard_channels=False)
    g2, s2, l2 = run(shard_channels=True)
    np.testing.assert_allclose(l1, l2, rtol=1e-12)
    for a, b in zip(jax.tree_util.tree_leaves(g1), jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(a, b, rtol=1e-12)
    for a, b in zip(jax.tree_util.tree_leaves(s1), jax.tree_util.tree_leaves(s2)):
        np.testing.assert_allclose(a, b, rtol=1e-12)
    print("OK sharded-channel parity", float(l1))


if __name__ == "__main__":
    main()
