"""Specialized-executor parity, in a subprocess with fake devices.

Usage: python spec_parity.py <schedule> <p> <m>

Runs the same (program, plan) through the generic scan executor and the
trace-time specialized executor and asserts the outputs are
*bit-identical*: loss, every stage/chunk gradient, every shared gradient.
Also checks the channel-liveness contract: the specialized program
contains exactly one ppermute per live (tick, channel) pair of the plan
(steady-window period counted once -- it compiles once inside the scan
superstep), while the generic program closes every used channel in its
single tick body.  Prints OK on success.
"""

import os
import sys

SCHED, P_, M_ = sys.argv[1], int(sys.argv[2]), int(sys.argv[3])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P_}"
os.environ["REPRO_PLAN_CACHE_DIR"] = "off"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.executor import PipelineExecutor, PipelineProgram
from repro.core.passes import auto_fbw
from repro.core.schedules import (
    compile_plan,
    one_f_one_b,
    v_half,
    v_min,
    zb_h1,
    zb_h2,
    zb_v,
)

D = 8
B = 2
jax.config.update("jax_enable_x64", True)
DT = jnp.float64


def layer_fn(p, x, side):
    return jnp.tanh(x @ p["w"] + p["b"])


def sink_fn(shared, y, side):
    return jnp.sum((y @ shared["w_out"] - side["target"]) ** 2) / M_


def src_fwd(shared, side_mb):
    return side_mb["x0"] @ shared["w_in"]


def src_bwd_w(shared, side_mb, dx):
    return {
        "w_in": side_mb["x0"].T @ dx,
        "w_out": jnp.zeros_like(shared["w_out"]),
    }


def count_ppermutes(jaxpr) -> int:
    """Static ppermute equations, recursing into sub-jaxprs (scan bodies,
    cond branches) -- each counted once, like the compiler sees them."""
    total = 0
    for eqn in jaxpr.eqns:
        if eqn.primitive.name == "ppermute":
            total += 1
        for val in eqn.params.values():
            for sub in _sub_jaxprs(val):
                total += count_ppermutes(sub)
    return total


def _sub_jaxprs(val):
    import jax.core as jcore

    if isinstance(val, jcore.ClosedJaxpr):
        yield val.jaxpr
    elif isinstance(val, jcore.Jaxpr):
        yield val
    elif isinstance(val, (tuple, list)):
        for v in val:
            yield from _sub_jaxprs(v)


def main():
    sched = {
        "1f1b": lambda: one_f_one_b(P_, M_),
        "zb-h1": lambda: zb_h1(P_, M_),
        "zb-h2": lambda: zb_h2(P_, M_),
        "zb-v": lambda: zb_v(P_, M_),
        "v-min": lambda: v_min(P_, M_),
        "v-half": lambda: v_half(P_, M_),
    }[SCHED]()
    plan = compile_plan(sched)
    C = plan.n_chunks

    key = jax.random.PRNGKey(0)
    keys = jax.random.split(key, P_ * C + 3)

    def mk(k):
        k1, k2 = jax.random.split(k)
        return {
            "w": (jax.random.normal(k1, (D, D)) * 0.5).astype(DT),
            "b": (jax.random.normal(k2, (D,)) * 0.1).astype(DT),
        }

    stacked = tuple(
        jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs), *[mk(keys[s * C + c]) for s in range(P_)]
        )
        for c in range(C)
    )
    shared = {
        "w_in": (jax.random.normal(keys[-1], (D, D)) * 0.5).astype(DT),
        "w_out": (jax.random.normal(keys[-2], (D, D)) * 0.5).astype(DT),
    }
    side = {
        "x0": jax.random.normal(keys[-3], (M_, B, D)).astype(DT),
        "target": jax.random.normal(
            jax.random.PRNGKey(7), (M_, B, D)
        ).astype(DT),
    }
    program = PipelineProgram(
        chunks=[auto_fbw(layer_fn, name=f"chunk{c}") for c in range(C)],
        src_fwd=src_fwd,
        src_bwd_w=src_bwd_w,
        sink=auto_fbw(sink_fn, name="sink"),
        act_shape=(B, D),
        act_dtype=DT,
    )
    mesh = jax.make_mesh((P_,), ("pipe",))
    spec_st = tuple(
        jax.tree_util.tree_map(lambda _: P("pipe"), sp) for sp in stacked
    )

    outs = {}
    fns = {}
    for mode in ("scan", "specialized"):
        execu = PipelineExecutor(program, plan, pipe_axis="pipe", mode=mode)
        grad_fn = execu.build_grad_fn()

        def body(st, sh, sd):
            local = tuple(
                jax.tree_util.tree_map(lambda a: a[0], sp) for sp in st
            )
            g, sg, l = grad_fn(local, sh, sd)
            g = tuple(
                jax.tree_util.tree_map(lambda a: a[None], x) for x in g
            )
            return g, sg, l

        fn = shard_map(
            body,
            mesh=mesh,
            in_specs=(spec_st, P(), P()),
            out_specs=(spec_st, P(), P()),
            check_rep=False,
        )
        fns[mode] = fn
        outs[mode] = jax.jit(fn)(stacked, shared, side)

    ga, sga, la = outs["scan"]
    gb, sgb, lb = outs["specialized"]
    assert float(la) == float(lb), f"loss not bit-identical: {la} vs {lb}"
    for a, b in zip(
        jax.tree_util.tree_leaves(ga), jax.tree_util.tree_leaves(gb)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    for a, b in zip(
        jax.tree_util.tree_leaves(sga), jax.tree_util.tree_leaves(sgb)
    ):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

    # -- channel-liveness contract ---------------------------------------- #
    live = plan.channel_liveness()  # (T, 4)
    sw = plan.steady_window()
    if sw is not None and sw.repeats >= 2:
        in_window = np.zeros(plan.n_ticks, bool)
        in_window[sw.start : sw.stop] = True
        expected = int(live[~in_window].sum()) + int(
            live[sw.start : sw.start + sw.period].sum()
        )
    else:
        expected = int(live.sum())
    jx = jax.make_jaxpr(fns["specialized"])(stacked, shared, side)
    got = count_ppermutes(jx.jaxpr)
    assert got == expected, (
        f"specialized program has {got} ppermutes, plan implies {expected}"
    )
    jx_gen = jax.make_jaxpr(fns["scan"])(stacked, shared, side)
    got_gen = count_ppermutes(jx_gen.jaxpr)
    n_used = len(plan.used_channels())
    assert got_gen == n_used, (
        f"generic program has {got_gen} ppermutes, expected {n_used} "
        "(one per used channel in the scanned tick body)"
    )
    print(
        "OK", SCHED, P_, M_, float(la),
        f"ppermutes={got} (generic tick body: {got_gen})",
    )


if __name__ == "__main__":
    main()
