"""Multi-pod numeric parity: DP=2 x PP=2 train step vs single-pipe reference.

The (pod, data) mesh splits the global batch across pods; after the dp psum
the loss and the updated parameters must match a single pipeline processing
the full batch (same schedule, m doubled).
"""

import os

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_reduced
from repro.core.schedules import compile_plan, zb_h1
from repro.launch.mesh import AxisBinding
from repro.launch.steps import TrainStepConfig, build_train_step
from repro.launch.train import side_from_batch
from repro.models.lm import RunSpec, init_params
from repro.optim import adamw


def make_state(cfg, spec, placement):
    stacked, shared = init_params(cfg, spec, placement)
    z = lambda t: jax.tree_util.tree_map(
        lambda a: jnp.zeros(a.shape, jnp.float32), t
    )
    opt = adamw.AdamWState(jnp.zeros((), jnp.int32), z(stacked), z(stacked))
    sopt = adamw.AdamWState(jnp.zeros((), jnp.int32), z(shared), z(shared))
    return stacked, shared, opt, sopt


def main():
    cfg = get_reduced("internlm2_1_8b")
    P_, B_, S_ = 2, 2, 16
    M_total = 8  # full batch microbatches
    sched_ref = zb_h1(P_, M_total)
    sched_dp = zb_h1(P_, M_total // 2)

    tokens = jax.random.randint(
        jax.random.PRNGKey(5), (M_total * B_, S_), 0, cfg.vocab
    )
    labels = jax.random.randint(
        jax.random.PRNGKey(6), (M_total * B_, S_), 0, cfg.vocab
    )
    batch = {"tokens": np.asarray(tokens), "labels": np.asarray(labels)}

    # ---- reference: single pipe, full batch ---------------------------- #
    spec_ref = RunSpec(p=P_, n_chunks=1, microbatch=B_, seq_len=S_, m=M_total)
    mesh_ref = jax.make_mesh((P_,), ("data",))
    bind_ref = AxisBinding(pipe="data", tp=None, dp=None)
    make_ref, _ = build_train_step(
        cfg, spec_ref, compile_plan(sched_ref), sched_ref.placement,
        mesh_ref, bind_ref, TrainStepConfig(),
    )
    state = make_state(cfg, spec_ref, sched_ref.placement)
    side = side_from_batch(batch, spec_ref, cfg=cfg)
    step_ref = make_ref(side)
    # snapshot before stepping: the jitted step donates its param inputs
    init_leaves = [
        np.asarray(a) for a in jax.tree_util.tree_leaves(state[0])
    ]
    p_ref, sh_ref, _, _, m_ref = step_ref(*state, side)

    # ---- DP=2 over "pod": each pod gets half the microbatches ---------- #
    spec_dp = RunSpec(
        p=P_, n_chunks=1, microbatch=B_, seq_len=S_, m=M_total // 2
    )
    mesh_dp = jax.make_mesh((2, P_), ("pod", "data"))
    bind_dp = AxisBinding(pipe="data", tp=None, dp="pod")
    make_dp, _ = build_train_step(
        cfg, spec_dp, compile_plan(sched_dp), sched_dp.placement,
        mesh_dp, bind_dp, TrainStepConfig(),
    )
    state_dp = make_state(cfg, spec_dp, sched_dp.placement)
    # identical init (same seed/config) as the reference
    for a, b in zip(
        init_leaves, jax.tree_util.tree_leaves(state_dp[0])
    ):
        np.testing.assert_array_equal(a, np.asarray(b))
    # global side leaves: (dp * m, b, s), sharded over "pod" on dim 0
    side_dp = {
        "tokens": tokens.reshape(M_total, B_, S_),
        "labels": labels.reshape(M_total, B_, S_),
        "positions": jnp.broadcast_to(jnp.arange(S_), (M_total, S_)),
    }
    step_dp = make_dp(side_dp)
    p_dp, sh_dp, _, _, m_dp = step_dp(*state_dp, side_dp)

    np.testing.assert_allclose(
        float(m_ref["loss"]) / 2.0,  # ref sink scales 1/M; dp pipes use 1/(M/2), then /dp
        float(m_dp["loss"]) / 2.0 * 1.0,
        rtol=2e-5,
    )
    for a, b in zip(
        jax.tree_util.tree_leaves(p_ref), jax.tree_util.tree_leaves(p_dp)
    ):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-4, atol=5e-5,
        )
    print("OK dp parity: loss", float(m_ref["loss"]), float(m_dp["loss"]))


if __name__ == "__main__":
    main()
