"""SPMD executor parity test: runs inside a subprocess with fake devices.

Usage: python exec_parity.py <schedule> <p> <m> <n_chunks>

Builds a toy deep-MLP pipeline model, runs the ticked executor on a
(p,)-device mesh, and checks loss + all gradients against a single-device
reference (same math, no pipeline).  Prints OK on success.
"""

import os
import sys

SCHED, P_, M_, C_ = sys.argv[1], int(sys.argv[2]), int(sys.argv[3]), int(sys.argv[4])
os.environ["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={P_}"

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P
from jax.experimental.shard_map import shard_map

from repro.core.executor import PipelineExecutor, PipelineProgram
from repro.core.passes import auto_fbw
from repro.core.schedules import (
    compile_plan,
    gpipe,
    interleaved_1f1b,
    one_f_one_b,
    v_half,
    v_min,
    zb_h1,
    zb_h2,
    zb_v,
)

D = 8  # hidden
B = 2  # microbatch size
jax.config.update("jax_enable_x64", True)
DT = jnp.float64


def layer_fn(p, x, side):
    return jnp.tanh(x @ p["w"] + p["b"])


def make_layer_params(key):
    k1, k2 = jax.random.split(key)
    return {
        "w": (jax.random.normal(k1, (D, D)) * 0.5).astype(DT),
        "b": (jax.random.normal(k2, (D,)) * 0.1).astype(DT),
    }


def sink_fn(shared, y, side):
    pred = y @ shared["w_out"]
    return jnp.sum((pred - side["target"]) ** 2) / M_


def src_fwd(shared, side_mb):
    return side_mb["x0"] @ shared["w_in"]


def src_bwd_w(shared, side_mb, dx):
    return {
        "w_in": side_mb["x0"].T @ dx,
        "w_out": jnp.zeros_like(shared["w_out"]),
    }


def main():
    sched = {
        "1f1b": lambda: one_f_one_b(P_, M_),
        "gpipe": lambda: gpipe(P_, M_),
        "zb-h1": lambda: zb_h1(P_, M_),
        "zb-h2": lambda: zb_h2(P_, M_),
        "zb-v": lambda: zb_v(P_, M_),
        "v-min": lambda: v_min(P_, M_),
        "v-half": lambda: v_half(P_, M_),
        "interleaved": lambda: interleaved_1f1b(P_, M_, v=C_),
    }[SCHED]()
    plan = compile_plan(sched)
    C = plan.n_chunks
    pl = sched.placement

    key = jax.random.PRNGKey(0)
    # distinct params per (stage, chunk)
    keys = jax.random.split(key, P_ * C + 3)
    stage_chunk_params = {
        (s, c): make_layer_params(keys[s * C + c])
        for s in range(P_)
        for c in range(C)
    }
    shared = {
        "w_in": (jax.random.normal(keys[-1], (D, D)) * 0.5).astype(DT),
        "w_out": (jax.random.normal(keys[-2], (D, D)) * 0.5).astype(DT),
    }
    side = {
        "x0": jax.random.normal(keys[-3], (M_, B, D)).astype(DT),
        "target": jax.random.normal(jax.random.PRNGKey(7), (M_, B, D)).astype(DT),
    }

    # ---------------- reference (no pipeline) ---------------------------- #
    def ref_loss(all_params, shared):
        total = 0.0
        for j in range(M_):
            x = side["x0"][j] @ shared["w_in"]
            for c in range(C):
                for k in range(P_):
                    s = pl.stage_of(c, k)
                    x = layer_fn(all_params[(s, c)], x, None)
            total = total + jnp.sum((x @ shared["w_out"] - side["target"][j]) ** 2) / M_
        return total

    ref_l, ref_grads = jax.value_and_grad(ref_loss, argnums=(0, 1))(
        stage_chunk_params, shared
    )

    # ---------------- pipelined ------------------------------------------ #
    program = PipelineProgram(
        chunks=[auto_fbw(layer_fn, name=f"chunk{c}") for c in range(C)],
        src_fwd=src_fwd,
        src_bwd_w=src_bwd_w,
        sink=auto_fbw(sink_fn, name="sink"),
        act_shape=(B, D),
        act_dtype=DT,
    )
    execu = PipelineExecutor(program, plan, pipe_axis="pipe")
    grad_fn = execu.build_grad_fn()

    mesh = jax.make_mesh((P_,), ("pipe",))
    # stack params: per chunk, leaves (p, ...)
    stacked = tuple(
        jax.tree_util.tree_map(
            lambda *xs: jnp.stack(xs),
            *[stage_chunk_params[(s, c)] for s in range(P_)],
        )
        for c in range(C)
    )

    def body(stacked_local, shared, side):
        local = tuple(
            jax.tree_util.tree_map(lambda a: a[0], sp) for sp in stacked_local
        )
        grads, shared_grads, loss = grad_fn(local, shared, side)
        grads = tuple(
            jax.tree_util.tree_map(lambda a: a[None], g) for g in grads
        )
        return grads, shared_grads, loss

    spec_stacked = tuple(
        jax.tree_util.tree_map(lambda _: P("pipe"), sp) for sp in stacked
    )
    fn = shard_map(
        body,
        mesh=mesh,
        in_specs=(spec_stacked, P(), P()),
        out_specs=(spec_stacked, P(), P()),
        check_rep=False,
    )
    grads, shared_grads, loss = jax.jit(fn)(stacked, shared, side)

    # ---------------- compare -------------------------------------------- #
    np.testing.assert_allclose(loss, ref_l, rtol=1e-9, atol=1e-9)
    for c in range(C):
        for s in range(P_):
            for k in ("w", "b"):
                got = grads[c][k][s]
                want = ref_grads[0][(s, c)][k]
                np.testing.assert_allclose(
                    got, want, rtol=1e-8, atol=1e-9,
                    err_msg=f"grad mismatch stage={s} chunk={c} {k}",
                )
    for k in ("w_in", "w_out"):
        np.testing.assert_allclose(
            shared_grads[k], ref_grads[1][k], rtol=1e-8, atol=1e-9,
            err_msg=f"shared grad {k}",
        )
    print("OK", SCHED, P_, M_, C_, float(loss))


if __name__ == "__main__":
    main()
