"""Buffer-donation check for the jitted train step (subprocess, fake devices).

Asserts that donating params/opt-state to the train step (the
launch/steps.py default) is clean on this backend: no "donated buffers
were not usable" warnings at execution, input buffers actually released,
and a second chained step runs fine.  Prints OK on success.
"""

import os
import warnings

os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
os.environ["REPRO_PLAN_CACHE_DIR"] = "off"

import jax

from repro.configs import get_reduced
from repro.core.schedules import compile_plan, zb_h1
from repro.data import DataConfig, SyntheticLM
from repro.launch.mesh import AxisBinding
from repro.launch.steps import TrainStepConfig, build_train_step
from repro.launch.train import side_from_batch
from repro.models.lm import RunSpec, init_params
from repro.optim import adamw


def main():
    p, m, b, s = 4, 8, 1, 16
    cfg = get_reduced("internlm2_1_8b")
    sched = zb_h1(p, m)
    plan = compile_plan(sched)
    spec = RunSpec(p=p, n_chunks=1, microbatch=b, seq_len=s, m=m)
    mesh = jax.make_mesh((p,), ("data",))
    binding = AxisBinding(pipe="data", tp=None, dp=None)
    make, _ = build_train_step(
        cfg, spec, plan, sched.placement, mesh, binding,
        TrainStepConfig(),  # donate=True is the default
    )
    data = SyntheticLM(DataConfig(global_batch=m * b, seq_len=s, vocab=cfg.vocab))
    side = side_from_batch(data.batch_at(0), spec, cfg=cfg)
    step = make(side)

    stacked, shared = init_params(cfg, spec, sched.placement)
    opt = adamw.init(stacked)
    shared_opt = adamw.init(shared)

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        out = step(stacked, shared, opt, shared_opt, side)
        jax.block_until_ready(out)
        # steady state: step N's outputs are step N+1's donated inputs --
        # already in the executable's sharding, so donation must take
        probe = jax.tree_util.tree_leaves(out[0])[0]
        out2 = step(*out[:4], side)
        jax.block_until_ready(out2)

    donation_warnings = [
        str(w.message) for w in caught if "donat" in str(w.message).lower()
    ]
    assert not donation_warnings, f"donation warnings: {donation_warnings}"
    assert probe.is_deleted(), "donated param buffer was not released"
    print("OK donation: no warnings, inputs released, loss",
          float(out2[4]["loss"]))


if __name__ == "__main__":
    main()
