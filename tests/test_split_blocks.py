"""Split-backward numerical parity per block kind (ISSUE 2 satellite),
plus the recurrent B/W split acceptance (ISSUE 4).

For every layer kind reachable from the dry-run shape grid
(configs/shapes.py enumerates ARCH_IDS; their block patterns cover the kinds
tested here), the dgrad/wgrad pair produced by the backward-jaxpr partition
(core/passes.auto_fbw) must reproduce the fused ``jax.vjp`` gradients:
``bwd_x`` returns the same dx, and ``bwd_w`` -- from the compact M_W context
alone, residuals freed -- the same parameter grads.  The loss/head sink path
(final norm + vocab-parallel CE) is covered too, as is the fused
``acc``-routing through kernels/wgrad_accum.

ISSUE 4 additions: parity holds through the *compact* partition (wrapper
inlining + byte-minimal cut + recursive scan split) for every kind, for
both RG-LRU recurrence forms, and for a weights-inside-scan RNN whose
per-step wgrad GEMMs must move into the W scan; the measured per-block
W-context bytes of the recurrent configs shrink >= 30% vs. the
whole-scan-in-B frontier baseline (``compact=False``).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.passes import _SynthScanEqn, auto_fbw
from repro.models.lm import ArchConfig, make_sink_fn
from repro.models.modules import ShardCtx, apply_block, apply_layer, init_layer

jax.config.update("jax_enable_x64", False)

# tolerances per dtype: fp32 kinds are tight; bf16 params lose ~8 bits
TOL = {"float32": dict(rtol=2e-5, atol=2e-5), "bfloat16": dict(rtol=2e-2, atol=2e-2)}

BASE = dict(
    d_model=16, n_heads=4, n_kv_heads=2, d_ff=32, n_layers=2, head_dim=4,
    tp_size=1,
)

# one tiny config per kind; every kind used by the shape-grid archs appears.
# "<kind>:<tag>" entries are extra variants of the same layer kind (the
# scanified RG-LRU fallback routes the recurrence through lax.scan).
KIND_CFG = {
    "attn": dict(BASE),
    "attn_local": dict(BASE, window=4),
    "mlp": dict(BASE),
    "mla": dict(BASE, q_lora_rank=8, kv_lora_rank=8, qk_rope_head_dim=4),
    "moe": dict(BASE, n_experts=4, topk=2, moe_d_ff=16, n_shared_experts=1,
                capacity=8),
    "slstm": dict(BASE),
    "mlstm": dict(BASE),
    "rglru": dict(BASE, lru_width=16),
    "rglru:seq": dict(BASE, lru_width=16, rglru_scan="sequential"),
    "encdec": dict(BASE, s_enc=4),
}


def test_kind_coverage_matches_shape_grid():
    """Every block kind in the configs/shapes.py grid has a parity case."""
    grid_kinds = {
        k
        for arch in ARCH_IDS
        for kinds in get_config(arch).block_pattern
        for k in kinds
    }
    assert grid_kinds <= set(KIND_CFG), sorted(grid_kinds - set(KIND_CFG))


def _block_case(kind, dtype):
    lcfg = KIND_CFG[kind]
    layer_kind = kind.split(":")[0]
    ctx = ShardCtx()
    key = jax.random.PRNGKey(0)
    params = init_layer(layer_kind, key, lcfg, ctx, dtype)
    b, s = 2, 8
    s_total = s + (lcfg["s_enc"] if layer_kind == "encdec" else 0)
    x = (jax.random.normal(jax.random.PRNGKey(1), (b, s_total, lcfg["d_model"]))
         * 0.5).astype(dtype)
    side = {"positions": jnp.arange(s_total)}

    def f(p, xx, sd):
        return apply_layer(layer_kind, p, xx, sd["positions"], lcfg, ctx)

    return f, params, x, side


@pytest.mark.parametrize("kind", sorted(KIND_CFG))
def test_split_backward_parity(kind):
    dtype = jnp.float32
    f, params, x, side = _block_case(kind, dtype)
    mod = auto_fbw(f, name=kind)
    y, res = mod.fwd(params, x, side)
    dy = (jax.random.normal(jax.random.PRNGKey(2), y.shape) * 0.5).astype(y.dtype)

    dx, wctx = mod.bwd_x(params, res, dy, side)
    grads = mod.bwd_w(params, wctx, side)

    ref_grads, ref_dx = jax.vjp(lambda p, xx: f(p, xx, side), params, x)[1](dy)
    tol = TOL["float32"]
    np.testing.assert_allclose(dx, ref_dx, **tol)
    flat = jax.tree_util.tree_leaves_with_path(grads)
    flat_ref = jax.tree_util.tree_leaves(ref_grads)
    for (path, g), rg in zip(flat, flat_ref):
        np.testing.assert_allclose(
            g, rg, err_msg=f"{kind}: wgrad mismatch at {jax.tree_util.keystr(path)}",
            **tol,
        )


def test_split_backward_parity_bf16():
    """Dtype-sensitive path: bf16 params, per-dtype tolerance."""
    f, params, x, side = _block_case("mlp", jnp.bfloat16)
    mod = auto_fbw(f, name="mlp_bf16")
    y, res = mod.fwd(params, x, side)
    dy = jnp.ones_like(y)
    dx, wctx = mod.bwd_x(params, res, dy, side)
    grads = mod.bwd_w(params, wctx, side)
    ref_grads, ref_dx = jax.vjp(lambda p, xx: f(p, xx, side), params, x)[1](dy)
    tol = TOL["bfloat16"]
    np.testing.assert_allclose(
        dx.astype(np.float32), ref_dx.astype(np.float32), **tol
    )
    for g, rg in zip(
        jax.tree_util.tree_leaves(grads), jax.tree_util.tree_leaves(ref_grads)
    ):
        np.testing.assert_allclose(
            g.astype(np.float32), rg.astype(np.float32), **tol
        )


def test_sink_split_parity():
    """Loss/head sink: final norm + vocab-parallel CE, split B/W vs vjp."""
    cfg = ArchConfig(
        name="sink_tiny", family="dense", n_layers=2, d_model=16, n_heads=2,
        n_kv_heads=2, d_ff=32, vocab=64,
    )
    ctx = ShardCtx()
    m = 4
    sink_fn = make_sink_fn(cfg, ctx, m)
    key = jax.random.PRNGKey(3)
    shared = {
        "embed": jax.random.normal(key, (64, 16)) * 0.02,
        "head": jax.random.normal(jax.random.fold_in(key, 1), (16, 64)) * 0.02,
        "final_ln": jnp.zeros((16,)),
    }
    b, s = 2, 8
    y = jax.random.normal(jax.random.fold_in(key, 2), (b, s, 16))
    side = {
        "labels": jax.random.randint(jax.random.fold_in(key, 3), (b, s), 0, 64),
        "positions": jnp.arange(s),
        "tokens": jax.random.randint(jax.random.fold_in(key, 4), (b, s), 0, 64),
    }
    mod = auto_fbw(sink_fn, name="sink")
    loss, res = mod.fwd(shared, y, side)
    ones = jnp.ones_like(loss)
    dy, wctx = mod.bwd_x(shared, res, ones, side)
    grads = mod.bwd_w(shared, wctx, side)
    ref_grads, ref_dy = jax.vjp(lambda sh, yy: sink_fn(sh, yy, side), shared, y)[
        1
    ](ones)
    tol = TOL["float32"]
    np.testing.assert_allclose(dy, ref_dy, **tol)
    for k in shared:
        np.testing.assert_allclose(
            grads[k], ref_grads[k], err_msg=f"sink grad {k}", **tol
        )


def test_wgrad_acc_fusion_routes_through_kernel():
    """bwd_w(acc=...) returns acc + grads, fusing terminal dW = a^T @ g
    outer products through kernels/wgrad_accum (fp32 accumulators only)."""
    f, params, x, side = _block_case("mlp", jnp.float32)
    mod = auto_fbw(f, name="mlp_acc")
    y, res = mod.fwd(params, x, side)
    dy = jnp.ones_like(y)
    _, wctx = mod.bwd_x(params, res, dy, side)
    grads = mod.bwd_w(params, wctx, side)
    acc = jax.tree_util.tree_map(
        lambda l: jnp.full(l.shape, 0.5, jnp.float32), params
    )
    fused = mod.bwd_w(params, wctx, side, acc=acc)
    plan = mod._split
    assert any(r is not None for r in plan.wgrad_routes), (
        "no dW = a^T @ g route matched for the MLP block"
    )
    for g, fg in zip(
        jax.tree_util.tree_leaves(grads), jax.tree_util.tree_leaves(fused)
    ):
        np.testing.assert_allclose(fg, 0.5 + g, rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------- #
# ISSUE 4: the recurrent B/W split + byte-minimal W-contexts
# --------------------------------------------------------------------- #
def _tree_bytes(t):
    return sum(
        int(np.prod(l.shape)) * jnp.dtype(l.dtype).itemsize
        for l in jax.tree_util.tree_leaves(t)
    )


def _block_split_case(kinds, lcfg):
    ctx = ShardCtx()
    kp = tuple(
        init_layer(k, jax.random.fold_in(jax.random.PRNGKey(0), i), lcfg, ctx,
                   jnp.float32)
        for i, k in enumerate(kinds)
    )
    params = (jnp.ones((), jnp.float32), kp)
    b, s = 2, 8
    x = jax.random.normal(jax.random.PRNGKey(1), (b, s, lcfg["d_model"])) * 0.5
    side = {"positions": jnp.arange(s)}

    def f(p, xx, sd):
        mask, bp = p
        return apply_block(kinds, mask, bp, xx, sd["positions"], lcfg, ctx)

    return f, params, x, side


@pytest.mark.parametrize(
    "kinds",
    [("slstm",), ("mlstm",), ("rglru", "mlp")],
    ids=lambda k: "+".join(k),
)
def test_compact_context_shrinks_recurrent_blocks(kinds):
    """ISSUE 4 acceptance core: >= 30% smaller M_W per recurrent block vs.
    the whole-scan-in-B frontier baseline, with exact grad parity between
    the two partitions."""
    lcfg = dict(BASE)
    if "rglru" in kinds:
        lcfg["lru_width"] = 16
    f, params, x, side = _block_split_case(kinds, lcfg)
    dy = (jax.random.normal(jax.random.PRNGKey(2), x.shape) * 0.5).astype(
        x.dtype
    )
    got = {}
    for compact in (False, True):
        mod = auto_fbw(f, name=f"{kinds}-{compact}", compact=compact)
        y, res = mod.fwd(params, x, side)
        dx, wctx = mod.bwd_x(params, res, dy, side)
        grads = mod.bwd_w(params, wctx, side)
        got[compact] = (_tree_bytes(wctx), dx, grads)
    base_bytes, dx0, g0 = got[False]
    compact_bytes, dx1, g1 = got[True]
    assert compact_bytes <= 0.70 * base_bytes, (
        f"{kinds}: compact W-context {compact_bytes}B > 70% of the "
        f"whole-scan-in-B baseline {base_bytes}B"
    )
    tol = TOL["float32"]
    np.testing.assert_allclose(dx1, dx0, **tol)
    for a, b_ in zip(
        jax.tree_util.tree_leaves(g0), jax.tree_util.tree_leaves(g1)
    ):
        np.testing.assert_allclose(a, b_, **tol)


def _rnn_case():
    """A true RNN: weights used *inside* the scan body, so the backward
    scan accumulates dW as a carry whose final value is dp-only."""

    def rnn(params, x, side):
        W, U, out = params["W"], params["U"], params["out"]

        def step(h, xt):
            h2 = jnp.tanh(xt @ W + h @ U)
            return h2, h2

        h0 = jnp.zeros((x.shape[0], W.shape[1]))
        _, hs = jax.lax.scan(step, h0, x.transpose(1, 0, 2))
        return hs.transpose(1, 0, 2) @ out

    key = jax.random.PRNGKey(0)
    params = {
        "W": jax.random.normal(key, (5, 4)) * 0.3,
        "U": jax.random.normal(jax.random.fold_in(key, 1), (4, 4)) * 0.3,
        "out": jax.random.normal(jax.random.fold_in(key, 2), (4, 3)) * 0.3,
    }
    x = jax.random.normal(jax.random.fold_in(key, 3), (2, 6, 5))
    return rnn, params, x


def test_scan_split_moves_wgrad_gemms_to_w():
    """Weights-inside-scan: the body partition must split the backward scan
    into a dx-only B scan and a W replay scan that owns the per-step wgrad
    GEMMs and the dW accumulator carries -- with full grad parity."""
    rnn, params, x = _rnn_case()
    mod = auto_fbw(rnn, name="rnn", compact=True)
    y, res = mod.fwd(params, x, {})
    dy = jax.random.normal(jax.random.PRNGKey(9), y.shape)
    dx, wctx = mod.bwd_x(params, res, dy, {})
    grads = mod.bwd_w(params, wctx, {})
    ref_g, ref_dx = jax.vjp(lambda p, xx: rnn(p, xx, {}), params, x)[1](dy)
    tol = TOL["float32"]
    np.testing.assert_allclose(dx, ref_dx, **tol)
    for k in params:
        np.testing.assert_allclose(grads[k], ref_g[k], err_msg=k, **tol)

    plan = mod._split
    halves = {
        e.primitive.name: e
        for e in plan.jaxpr.eqns
        if isinstance(e, _SynthScanEqn)
    }
    assert set(halves) == {"scan_b", "scan_w"}, sorted(halves)

    def body_dots(e):
        return sum(
            1
            for i in e.body_eqn_ids
            if e.body.eqns[i].primitive.name == "dot_general"
        )

    # W-x-grad GEMMs (xt@W, h@U transposes) stay in B; the per-step
    # dW = a^T g GEMMs for W and U run in the W replay scan
    assert body_dots(halves["scan_b"]) == 2
    assert body_dots(halves["scan_w"]) == 2
    # the dW accumulators ride the W scan as carries (2 of them: W and U)
    w_half = halves["scan_w"]
    assert len(w_half.invars) >= 2
    # and the B scan emits a per-step stacked context for the replay
    assert w_half.n_ctx >= 1

    # the poisoning property holds through the scan split too
    del res
    grads2 = mod.bwd_w(params, wctx, {})
    for a, b_ in zip(
        jax.tree_util.tree_leaves(grads), jax.tree_util.tree_leaves(grads2)
    ):
        np.testing.assert_array_equal(a, b_)


def test_scan_split_elementwise_weight_accumulator_parity():
    """Elementwise weight inside the scan body: the backward accumulates
    its grad as a param-shaped W-carry.  The body cut must never select a
    value computed *from* that carry (it exists only at W time), even when
    it is the byte-cheapest node on the chain -- regression for the
    W-carry availability hole in the body min-cut."""

    def f(params, x, side):
        u, out = params["u"], params["out"]

        def step(h, xt):
            h2 = jnp.tanh(xt + h * u)
            return h2, h2

        h0 = jnp.zeros((x.shape[0], x.shape[2]))
        _, hs = jax.lax.scan(step, h0, x.transpose(1, 0, 2))
        return hs.transpose(1, 0, 2) @ out

    key = jax.random.PRNGKey(4)
    params = {
        "u": jax.random.normal(key, (5,)) * 0.3,
        "out": jax.random.normal(jax.random.fold_in(key, 1), (5, 3)) * 0.3,
    }
    x = jax.random.normal(jax.random.fold_in(key, 2), (3, 7, 5))
    mod = auto_fbw(f, name="ew_rnn", compact=True)
    y, res = mod.fwd(params, x, {})
    dy = jax.random.normal(jax.random.fold_in(key, 3), y.shape)
    dx, wctx = mod.bwd_x(params, res, dy, {})
    grads = mod.bwd_w(params, wctx, {})
    ref_g, ref_dx = jax.vjp(lambda p, xx: f(p, xx, {}), params, x)[1](dy)
    tol = TOL["float32"]
    np.testing.assert_allclose(dx, ref_dx, **tol)
    for k in params:
        np.testing.assert_allclose(grads[k], ref_g[k], err_msg=k, **tol)


def test_dp_only_scan_runs_in_w():
    """A scan feeding only dparams must run wholly at W time: its equation
    (or synthetic replacement) sits in the W slice, none of it in B."""

    # dx = (1 + sum(c)) needs only the scan's *forward* value (a stored
    # residual); the dparams["gs"] pullback is a transposed scan that only
    # the W slice needs
    params = {
        "gs": jax.random.normal(jax.random.PRNGKey(0), (5, 4)) * 0.5,
    }
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 3))

    def g(params, x, side):
        def step(c, gt):
            return c * 0.9 + jnp.tanh(gt), None
        c, _ = jax.lax.scan(step, jnp.zeros((4,)), params["gs"])
        return x * (1.0 + jnp.sum(c))

    mod = auto_fbw(g, name="dponly", compact=True)
    y, res = mod.fwd(params, x, {})
    dy = jnp.ones_like(y)
    dx, wctx = mod.bwd_x(params, res, dy, {})
    grads = mod.bwd_w(params, wctx, {})
    ref_g, ref_dx = jax.vjp(lambda p, xx: g(p, xx, {}), params, x)[1](dy)
    tol = TOL["float32"]
    np.testing.assert_allclose(dx, ref_dx, **tol)
    np.testing.assert_allclose(grads["gs"], ref_g["gs"], **tol)

    plan = mod._split
    b_scans = [
        i
        for i in plan.b_eqns
        if isinstance(plan.jaxpr.eqns[i], _SynthScanEqn)
        or getattr(plan.jaxpr.eqns[i].primitive, "name", "") == "scan"
    ]
    w_scans = [
        i
        for i in plan.w_eqns
        if isinstance(plan.jaxpr.eqns[i], _SynthScanEqn)
        or getattr(plan.jaxpr.eqns[i].primitive, "name", "") == "scan"
    ]
    assert not b_scans, "dp-only backward scan leaked into the B slice"
    assert w_scans, "dp-only backward scan missing from the W slice"


def test_compat_env_flag_restores_frontier_cut(monkeypatch):
    """REPRO_SPLIT_COMPAT=1 falls back to the legacy frontier partition.

    No importlib.reload here: reloading would re-create the module's
    classes and break ``isinstance(..., _SynthScanEqn)`` checks in any
    test that runs afterwards.  The default is patched as a module attr
    (read at construction time); the env parsing is exercised in a clean
    subprocess.
    """
    import subprocess
    import sys

    import repro.core.passes as passes

    monkeypatch.setattr(passes, "_COMPACT_DEFAULT", False)
    mod = passes.auto_fbw(lambda p, x, sd: x * p, name="compat")
    assert mod.compact is False
    monkeypatch.setattr(passes, "_COMPACT_DEFAULT", True)
    assert passes.auto_fbw(lambda p, x, sd: x * p, name="c2").compact is True

    out = subprocess.run(
        [
            sys.executable,
            "-c",
            "import repro.core.passes as p; print(p._COMPACT_DEFAULT)",
        ],
        env={
            **__import__("os").environ,
            "REPRO_SPLIT_COMPAT": "1",
            "PYTHONPATH": "src",
        },
        capture_output=True,
        text=True,
        cwd=str(__import__("pathlib").Path(__file__).resolve().parent.parent),
        timeout=120,
    )
    assert out.returncode == 0, out.stderr
    assert out.stdout.strip() == "False"


def test_residuals_not_needed_after_b():
    """The W pass must run from the M_W context alone: poisoning the
    residual buffers after B changes nothing (true split, no rebuild)."""
    f, params, x, side = _block_case("attn", jnp.float32)
    mod = auto_fbw(f, name="attn_poison")
    y, res = mod.fwd(params, x, side)
    dy = jnp.ones_like(y)
    _, wctx = mod.bwd_x(params, res, dy, side)
    grads = mod.bwd_w(params, wctx, side)
    del res  # freed at B in the executor; bwd_w cannot touch it by design
    grads2 = mod.bwd_w(params, wctx, side)
    for a, b_ in zip(
        jax.tree_util.tree_leaves(grads), jax.tree_util.tree_leaves(grads2)
    ):
        np.testing.assert_array_equal(a, b_)
