"""Split-backward numerical parity per block kind (ISSUE 2 satellite).

For every layer kind reachable from the dry-run shape grid
(configs/shapes.py enumerates ARCH_IDS; their block patterns cover the kinds
tested here), the dgrad/wgrad pair produced by the backward-jaxpr partition
(core/passes.auto_fbw) must reproduce the fused ``jax.vjp`` gradients:
``bwd_x`` returns the same dx, and ``bwd_w`` -- from the compact M_W context
alone, residuals freed -- the same parameter grads.  The loss/head sink path
(final norm + vocab-parallel CE) is covered too, as is the fused
``acc``-routing through kernels/wgrad_accum.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, get_config
from repro.core.passes import auto_fbw
from repro.models.lm import ArchConfig, make_sink_fn
from repro.models.modules import ShardCtx, apply_layer, init_layer

jax.config.update("jax_enable_x64", False)

# tolerances per dtype: fp32 kinds are tight; bf16 params lose ~8 bits
TOL = {"float32": dict(rtol=2e-5, atol=2e-5), "bfloat16": dict(rtol=2e-2, atol=2e-2)}

BASE = dict(
    d_model=16, n_heads=4, n_kv_heads=2, d_ff=32, n_layers=2, head_dim=4,
    tp_size=1,
)

# one tiny config per kind; every kind used by the shape-grid archs appears
KIND_CFG = {
    "attn": dict(BASE),
    "attn_local": dict(BASE, window=4),
    "mlp": dict(BASE),
    "mla": dict(BASE, q_lora_rank=8, kv_lora_rank=8, qk_rope_head_dim=4),
    "moe": dict(BASE, n_experts=4, topk=2, moe_d_ff=16, n_shared_experts=1,
                capacity=8),
    "slstm": dict(BASE),
    "mlstm": dict(BASE),
    "rglru": dict(BASE, lru_width=16),
    "encdec": dict(BASE, s_enc=4),
}


def test_kind_coverage_matches_shape_grid():
    """Every block kind in the configs/shapes.py grid has a parity case."""
    grid_kinds = {
        k
        for arch in ARCH_IDS
        for kinds in get_config(arch).block_pattern
        for k in kinds
    }
    assert grid_kinds <= set(KIND_CFG), sorted(grid_kinds - set(KIND_CFG))


def _block_case(kind, dtype):
    lcfg = KIND_CFG[kind]
    ctx = ShardCtx()
    key = jax.random.PRNGKey(0)
    params = init_layer(kind, key, lcfg, ctx, dtype)
    b, s = 2, 8
    s_total = s + (lcfg["s_enc"] if kind == "encdec" else 0)
    x = (jax.random.normal(jax.random.PRNGKey(1), (b, s_total, lcfg["d_model"]))
         * 0.5).astype(dtype)
    side = {"positions": jnp.arange(s_total)}

    def f(p, xx, sd):
        return apply_layer(kind, p, xx, sd["positions"], lcfg, ctx)

    return f, params, x, side


@pytest.mark.parametrize("kind", sorted(KIND_CFG))
def test_split_backward_parity(kind):
    dtype = jnp.float32
    f, params, x, side = _block_case(kind, dtype)
    mod = auto_fbw(f, name=kind)
    y, res = mod.fwd(params, x, side)
    dy = (jax.random.normal(jax.random.PRNGKey(2), y.shape) * 0.5).astype(y.dtype)

    dx, wctx = mod.bwd_x(params, res, dy, side)
    grads = mod.bwd_w(params, wctx, side)

    ref_grads, ref_dx = jax.vjp(lambda p, xx: f(p, xx, side), params, x)[1](dy)
    tol = TOL["float32"]
    np.testing.assert_allclose(dx, ref_dx, **tol)
    flat = jax.tree_util.tree_leaves_with_path(grads)
    flat_ref = jax.tree_util.tree_leaves(ref_grads)
    for (path, g), rg in zip(flat, flat_ref):
        np.testing.assert_allclose(
            g, rg, err_msg=f"{kind}: wgrad mismatch at {jax.tree_util.keystr(path)}",
            **tol,
        )


def test_split_backward_parity_bf16():
    """Dtype-sensitive path: bf16 params, per-dtype tolerance."""
    f, params, x, side = _block_case("mlp", jnp.bfloat16)
    mod = auto_fbw(f, name="mlp_bf16")
    y, res = mod.fwd(params, x, side)
    dy = jnp.ones_like(y)
    dx, wctx = mod.bwd_x(params, res, dy, side)
    grads = mod.bwd_w(params, wctx, side)
    ref_grads, ref_dx = jax.vjp(lambda p, xx: f(p, xx, side), params, x)[1](dy)
    tol = TOL["bfloat16"]
    np.testing.assert_allclose(
        dx.astype(np.float32), ref_dx.astype(np.float32), **tol
    )
    for g, rg in zip(
        jax.tree_util.tree_leaves(grads), jax.tree_util.tree_leaves(ref_grads)
    ):
        np.testing.assert_allclose(
            g.astype(np.float32), rg.astype(np.float32), **tol
        )


def test_sink_split_parity():
    """Loss/head sink: final norm + vocab-parallel CE, split B/W vs vjp."""
    cfg = ArchConfig(
        name="sink_tiny", family="dense", n_layers=2, d_model=16, n_heads=2,
        n_kv_heads=2, d_ff=32, vocab=64,
    )
    ctx = ShardCtx()
    m = 4
    sink_fn = make_sink_fn(cfg, ctx, m)
    key = jax.random.PRNGKey(3)
    shared = {
        "embed": jax.random.normal(key, (64, 16)) * 0.02,
        "head": jax.random.normal(jax.random.fold_in(key, 1), (16, 64)) * 0.02,
        "final_ln": jnp.zeros((16,)),
    }
    b, s = 2, 8
    y = jax.random.normal(jax.random.fold_in(key, 2), (b, s, 16))
    side = {
        "labels": jax.random.randint(jax.random.fold_in(key, 3), (b, s), 0, 64),
        "positions": jnp.arange(s),
        "tokens": jax.random.randint(jax.random.fold_in(key, 4), (b, s), 0, 64),
    }
    mod = auto_fbw(sink_fn, name="sink")
    loss, res = mod.fwd(shared, y, side)
    ones = jnp.ones_like(loss)
    dy, wctx = mod.bwd_x(shared, res, ones, side)
    grads = mod.bwd_w(shared, wctx, side)
    ref_grads, ref_dy = jax.vjp(lambda sh, yy: sink_fn(sh, yy, side), shared, y)[
        1
    ](ones)
    tol = TOL["float32"]
    np.testing.assert_allclose(dy, ref_dy, **tol)
    for k in shared:
        np.testing.assert_allclose(
            grads[k], ref_grads[k], err_msg=f"sink grad {k}", **tol
        )


def test_wgrad_acc_fusion_routes_through_kernel():
    """bwd_w(acc=...) returns acc + grads, fusing terminal dW = a^T @ g
    outer products through kernels/wgrad_accum (fp32 accumulators only)."""
    f, params, x, side = _block_case("mlp", jnp.float32)
    mod = auto_fbw(f, name="mlp_acc")
    y, res = mod.fwd(params, x, side)
    dy = jnp.ones_like(y)
    _, wctx = mod.bwd_x(params, res, dy, side)
    grads = mod.bwd_w(params, wctx, side)
    acc = jax.tree_util.tree_map(
        lambda l: jnp.full(l.shape, 0.5, jnp.float32), params
    )
    fused = mod.bwd_w(params, wctx, side, acc=acc)
    plan = mod._split
    assert any(r is not None for r in plan.wgrad_routes), (
        "no dW = a^T @ g route matched for the MLP block"
    )
    for g, fg in zip(
        jax.tree_util.tree_leaves(grads), jax.tree_util.tree_leaves(fused)
    ):
        np.testing.assert_allclose(fg, 0.5 + g, rtol=2e-5, atol=2e-5)


def test_residuals_not_needed_after_b():
    """The W pass must run from the M_W context alone: poisoning the
    residual buffers after B changes nothing (true split, no rebuild)."""
    f, params, x, side = _block_case("attn", jnp.float32)
    mod = auto_fbw(f, name="attn_poison")
    y, res = mod.fwd(params, x, side)
    dy = jnp.ones_like(y)
    _, wctx = mod.bwd_x(params, res, dy, side)
    grads = mod.bwd_w(params, wctx, side)
    del res  # freed at B in the executor; bwd_w cannot touch it by design
    grads2 = mod.bwd_w(params, wctx, side)
    for a, b_ in zip(
        jax.tree_util.tree_leaves(grads), jax.tree_util.tree_leaves(grads2)
    ):
        np.testing.assert_array_equal(a, b_)
