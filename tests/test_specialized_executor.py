"""Trace-time specialized executor: parity, liveness, steady windows.

The specialized mode (DESIGN.md Sec. 8) must be a pure compilation-mode
change: bit-identical loss and gradients vs the generic scan executor on
every schedule family, with exactly the collectives the plan implies.
SPMD cases run in subprocesses so fake-device XLA flags never leak.
"""

import os
import subprocess
import sys

import numpy as np
import pytest

from repro.core.schedules import (
    compile_plan,
    one_f_one_b,
    v_half,
    v_min,
    zb_h1,
    zb_v,
)

SCRIPTS = os.path.join(os.path.dirname(__file__), "spmd_scripts")

CASES = [
    ("1f1b", 4, 8),
    ("zb-h1", 4, 8),
    ("zb-v", 4, 8),
    ("v-min", 4, 8),
    ("v-half", 4, 8),
    ("1f1b", 4, 12),  # long steady state: scan-superstep path
]


def _run(script, *args):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, os.path.join(SCRIPTS, script), *map(str, args)],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    assert "OK" in out.stdout
    return out.stdout


@pytest.mark.parametrize("sched,p,m", CASES)
def test_specialized_bit_parity_and_liveness(sched, p, m):
    """Bit-identical grads/loss + ppermute count == plan channel liveness."""
    _run("spec_parity.py", sched, p, m)


def test_donation_clean():
    """Donated params/opt-state: no warnings, inputs actually released."""
    _run("donation_check.py")


# --------------------------------------------------------------------- #
# steady-window detection (pure host-side, no devices)
# --------------------------------------------------------------------- #
def test_steady_window_found_and_valid():
    plan = compile_plan(one_f_one_b(4, 12))
    sw = plan.steady_window()
    assert sw is not None, "1F1B steady state must be detected"
    assert sw.repeats >= 2
    assert sw.stop <= plan.n_ticks
    # structural tables repeat exactly with the period inside the window
    for name in plan._STRUCT_TABLES:
        tab = getattr(plan, name)
        for i in range(sw.period):
            cols = [
                tab[:, sw.start + i + j * sw.period] for j in range(sw.repeats)
            ]
            for c in cols[1:]:
                np.testing.assert_array_equal(c, cols[0], err_msg=name)


def test_steady_window_saves_most_of_1f1b():
    """At m >> p the steady window must cover the bulk of the tick grid."""
    plan = compile_plan(one_f_one_b(4, 24))
    sw = plan.steady_window()
    assert sw is not None
    assert sw.saved_ticks() > plan.n_ticks // 3


def test_channel_liveness_consistent():
    for build in (one_f_one_b, zb_h1, zb_v, v_min, v_half):
        plan = compile_plan(build(4, 8))
        live = plan.channel_liveness()
        assert live.shape == (plan.n_ticks, 4)
        np.testing.assert_array_equal(
            live.sum(axis=0), plan.channel_live_ticks()
        )
        # edges exist exactly on live (tick, channel) pairs and are exact
        for t in range(plan.n_ticks):
            for d in range(4):
                edges = plan.channel_edges(t, d)
                assert bool(edges) == bool(live[t, d])
                for src, dst in edges:
                    assert plan.send_channel[src, t] == d
                    assert plan.recv_valid[dst, t, d]


def test_executor_mode_validation():
    from repro.core.executor import PipelineExecutor

    plan = compile_plan(one_f_one_b(2, 2))

    class _Prog:
        def n_chunks(self):
            return 1

    with pytest.raises(ValueError, match="unknown executor mode"):
        PipelineExecutor(_Prog(), plan, mode="turbo")
