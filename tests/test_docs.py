"""Project-docs integrity (ISSUE 4 satellite): README/DESIGN link and
verify-command checks, run by the CI docs job.

Checks are structural, not stylistic: every repo-relative path either doc
names must exist, the README's tier-1 verify command must match ROADMAP.md
verbatim (one source of truth for "how do I check this repo"), and the
DESIGN sections the in-tree docstrings cite must exist.
"""

import pathlib
import re

ROOT = pathlib.Path(__file__).resolve().parent.parent
README = (ROOT / "README.md").read_text()
DESIGN = (ROOT / "DESIGN.md").read_text()
ROADMAP = (ROOT / "ROADMAP.md").read_text()

# repo-relative paths that look like files/dirs: backtick-quoted tokens with
# a slash or a known extension, minus command lines and glob/placeholder bits
_PATH_RE = re.compile(r"`([A-Za-z0-9_./-]+\.(?:py|md|json|toml|yml))`")


def _referenced_paths(text):
    out = set()
    for m in _PATH_RE.finditer(text):
        p = m.group(1)
        if p.startswith(("http", "-", "$")) or "*" in p:
            continue
        out.add(p.rstrip("/"))
    return out


def _exists(p: str) -> bool:
    if any((c / p).exists() for c in (ROOT, ROOT / "src" / "repro")):
        return True
    if "/" not in p:  # bare file named in its package's context
        return any(ROOT.rglob(p))
    return False


def test_readme_paths_exist():
    missing = [p for p in sorted(_referenced_paths(README)) if not _exists(p)]
    assert not missing, f"README.md names missing files: {missing}"


def test_design_paths_exist():
    missing = [p for p in sorted(_referenced_paths(DESIGN)) if not _exists(p)]
    assert not missing, f"DESIGN.md names missing files: {missing}"


def test_readme_verify_command_matches_roadmap():
    m = re.search(r"\*\*Tier-1 verify:\*\*\s*`([^`]+)`", ROADMAP)
    assert m, "ROADMAP.md lost its tier-1 verify line"
    assert m.group(1) in README, (
        "README quickstart must carry the ROADMAP tier-1 verify command "
        f"verbatim: {m.group(1)!r}"
    )


def test_readme_architecture_map_covers_packages():
    src = ROOT / "src" / "repro"
    pkgs = {
        p.name
        for p in src.iterdir()
        if p.is_dir() and (p / "__init__.py").exists()
    }
    named = set(re.findall(r"^(\w+)/", README, flags=re.M))
    missing = pkgs - named - {"__pycache__"}
    assert not missing, f"README architecture map misses packages: {missing}"


def test_design_sections_cited_by_docstrings_exist():
    secs = set(re.findall(r"^## (\d+)\.", DESIGN, flags=re.M))
    cited = set()
    for py in (ROOT / "src").rglob("*.py"):
        cited |= set(re.findall(r"DESIGN\.md Sec\.\s*(\d+)", py.read_text()))
    missing = cited - secs
    assert not missing, f"docstrings cite missing DESIGN sections: {missing}"


def test_examples_named_in_readme_exist():
    for m in re.finditer(r"examples/(\w+)\.py", README):
        assert (ROOT / "examples" / f"{m.group(1)}.py").exists(), m.group(0)
