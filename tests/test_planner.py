"""Unified HBM-aware planning layer (ISSUE 3 acceptance).

``plan()`` searches every schedule family under a true per-device HBM
budget -- parameters, ZeRO-1-sharded optimizer state, channel/inbox/sink
buffers, activations and W-contexts -- and either returns a
fits-in-budget plan or an itemized infeasibility naming the binding term.

Covered here:
  * feasibility is monotone in the budget and the cost-vs-budget frontier
    never rises;
  * the itemized breakdown sums to the budget-facing total;
  * in measured fidelity the breakdown matches the executor's real buffer
    allocation plus independently-computed param/optimizer bytes within
    10% on a tiny-config grid;
  * the infeasibility report names the binding term;
  * a disk cache hit returns an identical plan, and the ``v_flex``
    portfolio inside ``auto.search(placement="v_flex")`` is replayed from
    disk by a *second process* (the portfolio builder is disabled there,
    so only the on-disk plan can produce the result);
  * ``calibrate_from_dryrun`` folds a compiled memory_analysis into the
    byte model as the XLA-temp fudge, within the documented tolerance.
"""

import json
import math
import os
import subprocess
import sys

import pytest

from repro.core.memory import ActivationByteModel, measured_timeline
from repro.core.planner import HBMPlanner, PlanReport, fastest_under_profile, plan
from repro.core.plan_cache import PlanCache
from repro.core.schedules import compile_plan, zb_h1
from repro.core.simulator import TimeModel
from repro.models.lm import ArchConfig

TINY = ArchConfig(
    name="tiny_planner", family="dense", n_layers=16, d_model=16, n_heads=2,
    n_kv_heads=2, d_ff=32, vocab=64,
)

P, M = 4, 8
RUN = dict(microbatch=2, seq_len=8)


def _planner(**kw) -> HBMPlanner:
    return HBMPlanner(TINY, p=P, m=M, times=TimeModel.unit(), **RUN, **kw)


# --------------------------------------------------------------------- #
# feasibility / monotonicity
# --------------------------------------------------------------------- #
def test_feasibility_monotone_in_budget():
    planner = _planner()
    totals = sorted(
        c.total_bytes for c in planner.candidates() if c.schedule is not None
    )
    lo, hi = 0.4 * totals[0], 1.3 * totals[-1]
    budgets = [lo + (hi - lo) * i / 9 for i in range(10)]
    prev_feasible = False
    prev_cost = None
    seen = {"feasible": False, "infeasible": False}
    for b in budgets:  # ascending
        r = planner.plan(b)
        seen["feasible" if r.feasible else "infeasible"] = True
        # once feasible, a larger budget can never become infeasible
        assert not (prev_feasible and not r.feasible)
        prev_feasible = r.feasible
        if r.feasible:
            assert r.chosen.total_bytes <= b + 1e-6
            if prev_cost is not None:
                assert r.chosen.cost <= prev_cost + 1e-9
            prev_cost = r.chosen.cost
        else:
            assert r.chosen is None
            assert r.min_required_bytes > b
    assert seen["feasible"] and seen["infeasible"]


def test_every_family_evaluated():
    r = _planner().plan(float("inf"))
    names = {p.name for p in r.plans}
    for required in (
        "1f1b", "zb-h1", "zb-h2", "zb-v", "v-half", "v-min",
        "1f1b-interleaved",
    ):
        assert required in names
    assert any(n.startswith("zb-auto@") for n in names)
    assert any(n.startswith("v-flex@") for n in names)
    # unbounded: every buildable family fits and one of them is chosen
    assert r.feasible
    for p in r.plans:
        if p.schedule is not None:
            assert p.fits


# --------------------------------------------------------------------- #
# breakdown itemization
# --------------------------------------------------------------------- #
def test_breakdown_sums_to_total():
    r = _planner().plan(float("inf"))
    for p in r.plans:
        if p.breakdown is None:
            continue
        items = p.breakdown.items()
        assert p.breakdown.total == pytest.approx(sum(items.values()))
        assert p.total_bytes == pytest.approx(p.breakdown.total)
        assert all(v >= 0 for v in items.values())


def test_breakdown_matches_measured_within_10pct():
    """Measured fidelity: executor + optimizer bytes, independently
    recomputed, match the plan's itemized breakdown on the tiny grid."""
    import jax

    from repro.core.executor import PipelineExecutor
    from repro.models.lm import RunSpec, build_program, init_params, side_inputs
    from repro.optim.sharding import zero1_state_bytes

    planner = _planner(measured=True)
    r = planner.plan(float("inf"))
    assert r.feasible
    checked = 0
    for pp in r.plans:
        if pp.schedule is None:
            continue
        sched = pp.schedule
        spec = RunSpec(p=P, n_chunks=sched.n_chunks, m=M, **RUN)
        prog = build_program(TINY, spec, sched.placement)
        stacked, shared = init_params(TINY, spec, sched.placement)
        sp = tuple(
            jax.tree_util.tree_map(
                lambda a: jax.ShapeDtypeStruct(a.shape[1:], a.dtype), s
            )
            for s in stacked
        )
        side = side_inputs(TINY, spec)
        exe = PipelineExecutor(prog, compile_plan(sched), pipe_axis="pipe")
        mt = measured_timeline(exe, sp, shared, side)
        bd = pp.breakdown
        # executor share: act + wctx + inbox + sink == real allocation
        assert bd.schedule_bytes == pytest.approx(mt.alloc_total, rel=0.10)
        assert bd.act == pytest.approx(mt.alloc_act, rel=0.10)
        assert bd.wctx == pytest.approx(mt.alloc_wctx, rel=0.10)
        # optimizer share: ZeRO-1 moments of the real param shapes
        opt_ref = zero1_state_bytes(sp, 1) + zero1_state_bytes(shared, 1)
        assert bd.optim == pytest.approx(opt_ref, rel=0.10)
        # params: real per-device array bytes
        import numpy as np

        param_ref = sum(
            a.size * a.dtype.itemsize
            for a in map(np.asarray, jax.tree_util.tree_leaves(shared))
        ) + sum(
            np.prod(l.shape) * np.dtype(l.dtype).itemsize
            for c in sp
            for l in jax.tree_util.tree_leaves(c)
        )
        assert bd.params == pytest.approx(param_ref, rel=0.10)
        checked += 1
    assert checked >= 6  # the whole family, not a lucky single candidate


def test_infeasibility_names_binding_term():
    planner = _planner()
    r = planner.plan(1.0)  # one byte: nothing fits
    assert not r.feasible
    report = r.infeasibility_report()
    assert "binding term:" in report
    cheapest = min(
        (p for p in r.plans if p.schedule is not None),
        key=lambda p: p.total_bytes,
    )
    binding = cheapest.breakdown.binding_term()
    assert binding in report
    # the named term really is the largest item
    items = cheapest.breakdown.items()
    assert items[binding] == max(items.values())


# --------------------------------------------------------------------- #
# disk cache
# --------------------------------------------------------------------- #
def test_disk_cache_hit_returns_identical_plan(tmp_path):
    cache = PlanCache(str(tmp_path))
    kw = dict(
        hbm_budget_bytes=1 << 30, cache=cache, **RUN
    )
    a = plan(TINY, P, M, TimeModel.unit(), **kw)
    assert not a.from_cache
    b = plan(TINY, P, M, TimeModel.unit(), **kw)
    assert b.from_cache
    assert b.feasible == a.feasible
    assert b.chosen.name == a.chosen.name
    assert b.chosen.cost == pytest.approx(a.chosen.cost)
    assert b.chosen.total_bytes == pytest.approx(a.chosen.total_bytes)
    assert b.chosen.breakdown.items() == pytest.approx(
        a.chosen.breakdown.items()
    )
    assert [
        [repr(op) for op in ops] for ops in b.chosen.schedule.stage_ops
    ] == [[repr(op) for op in ops] for ops in a.chosen.schedule.stage_ops]
    b.chosen.schedule.validate()
    # a different budget is a different content key
    c = plan(TINY, P, M, TimeModel.unit(), hbm_budget_bytes=2 << 30,
             cache=cache, **RUN)
    assert not c.from_cache


_VFLEX_SCRIPT = """
import hashlib, sys
{patch}
from repro.core.schedules import auto
from repro.core.simulator import TimeModel

r = auto.search(4, 8, TimeModel.unit(), m_limit=4.0, placement="v_flex")
blob = repr([[repr(o) for o in ops] for ops in r.schedule.stage_ops])
print("OPS", hashlib.sha256(blob.encode()).hexdigest())
"""

_DISABLE_PORTFOLIO = """
import repro.core.schedules.vflex as vflex
def _no_build(*a, **k):
    raise AssertionError("portfolio rebuilt: disk cache was not used")
vflex._v_flex_portfolio = _no_build
"""


def test_vflex_search_cached_on_disk_across_processes(tmp_path):
    """auto.search(placement='v_flex') must replay the portfolio from the
    on-disk cache in a second process -- run 2 has the builder disabled, so
    only a disk hit can produce the (identical) result."""
    env = dict(os.environ)
    env["REPRO_PLAN_CACHE_DIR"] = str(tmp_path)
    env["PYTHONPATH"] = (
        os.path.join(os.path.dirname(__file__), "..", "src")
        + os.pathsep
        + env.get("PYTHONPATH", "")
    )

    def run(patch):
        out = subprocess.run(
            [sys.executable, "-c", _VFLEX_SCRIPT.format(patch=patch)],
            env=env, capture_output=True, text=True, timeout=300,
        )
        assert out.returncode == 0, out.stderr
        return [l for l in out.stdout.splitlines() if l.startswith("OPS ")][0]

    first = run("")
    assert any(f.startswith("v_flex-") for f in os.listdir(tmp_path))
    second = run(_DISABLE_PORTFOLIO)
    assert first == second


# --------------------------------------------------------------------- #
# dryrun calibration
# --------------------------------------------------------------------- #
def test_calibrate_from_dryrun_tolerance():
    model = ActivationByteModel.from_config(TINY, 2, 8, P)
    sched = zb_h1(P, M)
    modeled = model.schedule_bytes(sched)[2]
    # a compiled temp footprint 1.5x the modeled schedule bytes: the excess
    # becomes the fudge, within float tolerance
    temp = 1.5 * modeled
    cal = model.calibrate_from_dryrun({"temp_size_in_bytes": temp}, sched)
    assert cal.xla_temp_bytes == pytest.approx(0.5 * modeled, rel=1e-6)
    # a temp footprint the model already covers leaves no fudge
    cal0 = model.calibrate_from_dryrun(
        {"temp_size_in_bytes": 0.5 * modeled}, sched
    )
    assert cal0.xla_temp_bytes == 0.0
    # dict fallback key (dryrun result records) and object attrs both work
    class Mem:
        temp_size_in_bytes = temp

    assert model.calibrate_from_dryrun(Mem(), sched).xla_temp_bytes == (
        pytest.approx(cal.xla_temp_bytes)
    )
    # the planner charges the fudge against the budget on every candidate
    fudge = 123456.0
    r0 = _planner().plan(float("inf"))
    r1 = _planner(xla_temp_bytes=fudge).plan(float("inf"))
    by_name0 = {p.name: p for p in r0.plans if p.schedule is not None}
    for p in r1.plans:
        if p.schedule is None or p.name not in by_name0:
            continue
        assert p.total_bytes == pytest.approx(
            by_name0[p.name].total_bytes + fudge
        )


def test_checked_in_calibration_table_loads_and_scales():
    """The shipped {arch: xla_temp} table (ROADMAP open item 1) feeds the
    byte model by default, scaled to the run shape and never upward."""
    import json
    import pathlib

    from repro.configs import get_config
    from repro.core.memory import default_xla_temp_bytes

    root = pathlib.Path(__file__).resolve().parent.parent
    table = json.loads(
        (root / "src/repro/configs/xla_temp_calibration.json").read_text()
    )
    assert len(table) >= 10  # the full train grid is calibrated
    for name, rec in table.items():
        assert rec["xla_temp_bytes"] > 0
        assert rec["m_b_bytes"] > 0

    cfg = get_config("gpt3_1_5b")
    rec = table[cfg.name]
    # exactly the raw value at the calibration cell's own shape
    at_cal = default_xla_temp_bytes(
        cfg.name, tokens=rec["tokens"], m_b_bytes=rec["m_b_bytes"]
    )
    assert at_cal == pytest.approx(rec["xla_temp_bytes"])
    # smaller shapes scale down, larger shapes never extrapolate upward
    half = default_xla_temp_bytes(
        cfg.name, tokens=rec["tokens"] // 2, m_b_bytes=rec["m_b_bytes"] / 2
    )
    assert half == pytest.approx(rec["xla_temp_bytes"] / 2)
    big = default_xla_temp_bytes(
        cfg.name, tokens=rec["tokens"] * 4, m_b_bytes=rec["m_b_bytes"] * 4
    )
    assert big <= rec["xla_temp_bytes"] * (1 + 1e-9)
    assert default_xla_temp_bytes("no-such-arch", tokens=1) == 0.0

    # from_config folds it in; the planner charges it by default
    bm = ActivationByteModel.from_config(cfg, 1, 2048, 4)
    assert bm.xla_temp_bytes > 0
    planner = HBMPlanner(cfg, p=4, m=8, microbatch=1, seq_len=2048)
    assert planner.xla_temp_bytes == pytest.approx(bm.xla_temp_bytes)
    # reduced() variants share the name but price proportionally smaller
    import repro.configs.gpt3_1_5b as mod

    if hasattr(mod, "reduced"):
        red = ActivationByteModel.from_config(mod.reduced(), 2, 8, 4)
        assert red.xla_temp_bytes < bm.xla_temp_bytes / 100


def test_tp_param_bytes_per_leaf_not_uniform():
    """tp>1 params/optimizer derive from sharding_rules specs per leaf:
    replicated leaves (norms, lam, recurrent weights) keep full bytes, so
    the total sits strictly between full/tp and full."""
    from repro.core.planner import fixed_state_bytes

    for arch in ("gpt3_1_5b", "xlstm_350m"):
        cfg = __import__(
            f"repro.configs.{arch}", fromlist=["reduced"]
        ).reduced()
        p1, o1 = fixed_state_bytes(cfg, p=2, n_chunks=1, tp_size=1)
        p2, o2 = fixed_state_bytes(cfg, p=2, n_chunks=1, tp_size=2)
        assert p1 / 2 < p2 < p1, (arch, p1, p2)
        assert o1 / 2 < o2 < o1, (arch, o1, o2)
    # xlstm keeps its recurrent weights replicated: far less tp benefit
    # than the dense transformer at the same degree
    gpt = __import__("repro.configs.gpt3_1_5b", fromlist=["reduced"]).reduced()
    xl = __import__("repro.configs.xlstm_350m", fromlist=["reduced"]).reduced()
    g1, _ = fixed_state_bytes(gpt, 2, 1, tp_size=1)
    g2, _ = fixed_state_bytes(gpt, 2, 1, tp_size=2)
    x1, _ = fixed_state_bytes(xl, 2, 1, tp_size=1)
    x2, _ = fixed_state_bytes(xl, 2, 1, tp_size=2)
    assert (x2 / x1) > (g2 / g1)


def test_local_leaf_shape_rules():
    from jax.sharding import PartitionSpec as PS

    from repro.launch.sharding_rules import local_leaf_shape

    assert local_leaf_shape((8, 6), PS(None, "tp"), {"tp": 2}) == (8, 3)
    assert local_leaf_shape((8, 6), PS("tp"), {"tp": 2}) == (4, 6)
    assert local_leaf_shape((8, 6), PS(), {"tp": 2}) == (8, 6)
    # padded division rounds up (runtime pads before sharding)
    assert local_leaf_shape((7,), PS("tp"), {"tp": 2}) == (4,)
    # unknown axis names leave the dim whole
    assert local_leaf_shape((8,), PS("other"), {"tp": 2}) == (8,)


# --------------------------------------------------------------------- #
# straggler-facing family search
# --------------------------------------------------------------------- #
def test_fastest_under_profile_respects_limit():
    times = TimeModel(1.0, 1.0, 1.0, 0.0)
    sched, cost = fastest_under_profile(P, M, times, m_limit=float(P))
    sched.validate()
    C = sched.n_chunks
    assert (
        sched.memory_profile(1.0 / C, 0.5 / C).max_peak <= P + 1e-9
    )
    assert math.isfinite(cost)
    # a laxer limit can only help
    _, cost2 = fastest_under_profile(P, M, times, m_limit=2.0 * P)
    assert cost2 <= cost + 1e-9
