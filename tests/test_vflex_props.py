"""Property tests for the v_flex admission gates and W-bank bounds.

Random ``(p, m, clip level)`` draws must always yield a schedule that (a)
respects the activation cap the admission gates enforce, (b) is
deadlock-free, and (c) compiles to an execution plan whose *joint* F->B
residual pool (what the tick executor actually allocates) stays within the
cap -- i.e. the byte-level claim holds structurally, not just on the grid
points the acceptance tests pin.  Runs offline via the seeded hypothesis
fallback in tests/conftest.py when the real engine is absent.
"""

import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core.schedules import compile_plan, v_flex, v_min_limit
from repro.core.schedules.vflex import (
    _v_flex_build,
    _wctx_backlog_peak,
    activation_peak,
)


@given(
    p=st.sampled_from([3, 4, 5, 6, 8]),
    mfac=st.integers(2, 3),
    clip=st.integers(0, 4),
)
@settings(max_examples=12, deadline=None)
def test_vflex_cap_and_liveness(p, mfac, clip):
    m = mfac * p
    limit = v_min_limit(p) + clip  # clip levels from V-Min up to ~ZB-V
    sched = v_flex(p, m, limit, name=f"v@{limit}")

    # (a) admission gates: the activation cap holds in schedule order
    assert activation_peak(sched) <= limit + 1e-9
    # (b) no deadlock: the tick compiler finds a valid order
    sched.validate()
    # (c) the executor's joint residual pool realizes the cap in slots
    plan = compile_plan(sched)
    assert plan.n_res_slots_joint <= int(2 * limit) + 1
    # residual slots cannot exceed in-flight microbatches per chunk
    assert all(n <= m for n in plan.n_res_slots)
    # (d) W-bank bound: the B->W backlog never exceeds the in-flight set
    assert _wctx_backlog_peak(sched) <= 2 * m


@given(
    p=st.sampled_from([4, 6]),
    mfac=st.integers(2, 3),
)
@settings(max_examples=6, deadline=None)
def test_vflex_memoized_rebuilds_are_equal(p, mfac):
    """The in-process LRU returns structurally identical schedules, and
    mutating one (e.g. renaming) never leaks into the cache."""
    m = mfac * p
    limit = v_min_limit(p)
    a = v_flex(p, m, limit, name="first")
    a_ops = [list(ops) for ops in a.stage_ops]
    a.name = "mutated"
    a.stage_ops[0].reverse()  # vandalize the returned copy
    b = v_flex(p, m, limit, name="second")
    assert b.name == "second"
    assert [list(ops) for ops in b.stage_ops] == a_ops
    assert _v_flex_build.cache_info().hits >= 1


def test_vflex_infeasible_limit_raises():
    with pytest.raises((ValueError, RuntimeError)):
        v_flex(4, 8, 0.4)  # below one V chunk pair
