"""SPMD executor parity vs single-device reference, per schedule family.

Each case runs in a subprocess so the fake-device XLA flag never leaks into
other tests.  float64 + tight tolerances: the pipeline must be numerically
*identical* to no-pipeline training (the paper verifies bit-identical losses
against Megatron 1F1B the same way, Sec. 5.1).
"""

import os
import subprocess
import sys

import pytest

SCRIPT = os.path.join(os.path.dirname(__file__), "spmd_scripts", "exec_parity.py")

CASES = [
    ("1f1b", 4, 8, 1),
    ("zb-h1", 4, 8, 1),
    ("zb-h2", 4, 8, 1),
    ("zb-v", 4, 8, 2),
    ("interleaved", 4, 8, 2),
    ("gpipe", 3, 5, 1),
    ("zb-h2", 3, 9, 1),
    ("zb-v", 3, 6, 2),
    ("v-min", 4, 8, 2),
    ("v-half", 4, 8, 2),
]


@pytest.mark.parametrize("sched,p,m,c", CASES)
def test_executor_parity(sched, p, m, c):
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    out = subprocess.run(
        [sys.executable, SCRIPT, sched, str(p), str(m), str(c)],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert out.returncode == 0, f"{sched}: {out.stderr[-2000:]}"
    assert "OK" in out.stdout


def test_sharded_channel_parity():
    """Sequence-sharded pipe channels (pipe=2 x tp=2): exact grad parity."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    script = os.path.join(
        os.path.dirname(__file__), "spmd_scripts", "tp_channel_parity.py"
    )
    out = subprocess.run(
        [sys.executable, script],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout


def test_multipod_dp_parity():
    """DP=2 x PP=2 (pod, data) mesh: loss + updated params equal the
    single-pipe full-batch reference (the multi-pod data path, numerically)."""
    env = dict(os.environ)
    env.pop("XLA_FLAGS", None)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src")
    )
    script = os.path.join(
        os.path.dirname(__file__), "spmd_scripts", "dp_parity.py"
    )
    out = subprocess.run(
        [sys.executable, script],
        capture_output=True,
        text=True,
        env=env,
        timeout=900,
    )
    assert out.returncode == 0, out.stderr[-2000:]
    assert "OK" in out.stdout
