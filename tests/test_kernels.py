"""Pallas kernel correctness: interpret-mode allclose vs ref.py oracles,
swept over shapes / dtypes / tilings (hypothesis for the invariants)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.kernels.ref import rmsnorm_ref, wgrad_accum_ref
from repro.kernels.rmsnorm import rmsnorm_fused
from repro.kernels.wgrad_accum import wgrad_accum
from repro.kernels import ops


def _rand(key, shape, dtype):
    return (jax.random.normal(key, shape) * 0.5).astype(dtype)


WGRAD_SHAPES = [
    # (n, h, f, bn, bh, bf)
    (256, 128, 128, 64, 64, 128),
    (512, 256, 128, 128, 128, 128),
    (128, 128, 512, 128, 128, 128),
    (1024, 128, 256, 512, 128, 128),
]


@pytest.mark.parametrize("n,h,f,bn,bh,bf", WGRAD_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wgrad_accum_matches_ref(n, h, f, bn, bh, bf, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    a = _rand(ks[0], (n, h), dtype)
    g = _rand(ks[1], (n, f), dtype)
    acc = _rand(ks[2], (h, f), jnp.float32)
    out = wgrad_accum(a, g, acc, bh=bh, bf=bf, bn=bn, interpret=True)
    ref = wgrad_accum_ref(a, g, acc)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


@given(
    n_blocks=st.integers(1, 4),
    h_blocks=st.integers(1, 2),
    seed=st.integers(0, 20),
)
@settings(max_examples=10, deadline=None)
def test_wgrad_accum_property(n_blocks, h_blocks, seed):
    n, h, f = 64 * n_blocks, 64 * h_blocks, 128
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    a = _rand(ks[0], (n, h), jnp.float32)
    g = _rand(ks[1], (n, f), jnp.float32)
    acc = _rand(ks[2], (h, f), jnp.float32)
    out = wgrad_accum(a, g, acc, bh=64, bf=128, bn=64, interpret=True)
    np.testing.assert_allclose(
        out, wgrad_accum_ref(a, g, acc), rtol=2e-5, atol=2e-5
    )


RMS_SHAPES = [(256, 128, 64), (512, 1024, 256), (128, 384, 128)]


@pytest.mark.parametrize("n,h,br", RMS_SHAPES)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rmsnorm_matches_ref(n, h, br, dtype):
    ks = jax.random.split(jax.random.PRNGKey(1), 2)
    x = _rand(ks[0], (n, h), dtype)
    g = _rand(ks[1], (h,), jnp.float32)
    out = rmsnorm_fused(x, g, br=br, interpret=True)
    ref = rmsnorm_ref(x, g)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(
        np.asarray(out, np.float32), np.asarray(ref, np.float32), rtol=tol, atol=tol
    )


def test_rmsnorm_custom_vjp_matches_autodiff():
    ks = jax.random.split(jax.random.PRNGKey(2), 2)
    x = _rand(ks[0], (32, 64), jnp.float32)
    g = _rand(ks[1], (64,), jnp.float32)

    def f_ops(x, g):
        return jnp.sum(ops.rmsnorm(x, g) ** 2)

    def f_ref(x, g):
        return jnp.sum(rmsnorm_ref(x, g) ** 2)

    dx1, dg1 = jax.grad(f_ops, argnums=(0, 1))(x, g)
    dx2, dg2 = jax.grad(f_ref, argnums=(0, 1))(x, g)
    np.testing.assert_allclose(dx1, dx2, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(dg1, dg2, rtol=1e-4, atol=1e-5)


def test_wgrad_hbm_traffic_savings():
    """The fusion claim: unfused = matmul out + add (2 extra acc-sized HBM
    round trips); verify against XLA's bytes-accessed estimate."""
    n, h, f = 512, 256, 256
    a = jnp.ones((n, h), jnp.bfloat16)
    g = jnp.ones((n, f), jnp.bfloat16)
    acc = jnp.ones((h, f), jnp.float32)

    def unfused(a, g, acc):
        return acc + jax.lax.dot_general(
            a, g, (((0,), (0,)), ((), ())), preferred_element_type=jnp.float32
        )

    cost = jax.jit(unfused).lower(a, g, acc).compile().cost_analysis()
    if isinstance(cost, (list, tuple)):  # one dict per device program
        cost = cost[0]
    # inputs + matmul-out write + add read + add write >= 3 acc-sized arrays
    assert cost["bytes accessed"] >= (a.size * 2 + g.size * 2 + 3 * acc.size * 4) * 0.9
