"""Shared test fixtures; provides a hypothesis fallback for offline runs.

The property tests use ``hypothesis`` when it is installed.  This container
has no network and no hypothesis wheel, so ``import hypothesis`` raises and
four test modules used to fail at collection.  When the real package is
missing we register a minimal seeded-random stand-in under the same module
names *before* the test modules import it: ``@given`` draws
``max_examples`` pseudo-random examples from the declared strategies and
runs the test once per draw (deterministic per test, seeded from the test's
qualified name).  The stand-in covers exactly the API surface the test
suite uses: ``given``, ``settings``, ``assume``, and the ``integers`` /
``sampled_from`` / ``booleans`` / ``floats`` strategies.
"""

import functools
import inspect
import random
import sys
import types
import zlib

try:  # pragma: no cover - prefer the real property-testing engine
    import hypothesis  # noqa: F401
except ImportError:
    _DEFAULT_MAX_EXAMPLES = 10

    class _Strategy:
        def __init__(self, draw):
            self.draw = draw

        def map(self, fn):
            return _Strategy(lambda rng: fn(self.draw(rng)))

        def filter(self, pred):
            def draw(rng):
                for _ in range(1000):
                    x = self.draw(rng)
                    if pred(x):
                        return x
                raise ValueError("filter predicate too strict")

            return _Strategy(draw)

    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    def sampled_from(seq):
        items = list(seq)
        return _Strategy(lambda rng: rng.choice(items))

    def booleans():
        return _Strategy(lambda rng: rng.random() < 0.5)

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    class _Assumption(Exception):
        pass

    def assume(condition):
        if not condition:
            raise _Assumption()
        return True

    def given(**strategies):
        def deco(fn):
            @functools.wraps(fn)
            def wrapper(*args, **kwargs):
                n = getattr(wrapper, "_hyp_max_examples", None) or getattr(
                    fn, "_hyp_max_examples", _DEFAULT_MAX_EXAMPLES
                )
                seed = zlib.crc32(fn.__qualname__.encode())
                rng = random.Random(seed)
                for _ in range(n):
                    drawn = {k: s.draw(rng) for k, s in strategies.items()}
                    try:
                        fn(*args, **kwargs, **drawn)
                    except _Assumption:
                        continue

            # hide the drawn parameters from pytest's fixture resolution:
            # only the test's non-strategy parameters remain visible
            params = [
                prm
                for name, prm in inspect.signature(fn).parameters.items()
                if name not in strategies
            ]
            wrapper.__signature__ = inspect.Signature(params)
            del wrapper.__wrapped__
            return wrapper

        return deco

    def settings(max_examples=_DEFAULT_MAX_EXAMPLES, **_kw):
        def deco(fn):
            fn._hyp_max_examples = max_examples
            return fn

        return deco

    _hyp = types.ModuleType("hypothesis")
    _hyp.given = given
    _hyp.settings = settings
    _hyp.assume = assume
    _hyp.HealthCheck = types.SimpleNamespace(all=lambda: [])
    _st = types.ModuleType("hypothesis.strategies")
    _st.integers = integers
    _st.sampled_from = sampled_from
    _st.booleans = booleans
    _st.floats = floats
    _hyp.strategies = _st
    sys.modules["hypothesis"] = _hyp
    sys.modules["hypothesis.strategies"] = _st


# ----------------------------------------------------------------------- #
# plan-cache isolation: the scheduling layer persists plans on disk
# (repro.core.plan_cache); tests must neither read a developer's warm cache
# nor leave entries behind, so the whole session runs against a tmp dir
# unless a test explicitly overrides REPRO_PLAN_CACHE_DIR itself.
# ----------------------------------------------------------------------- #
import pytest


@pytest.fixture(autouse=True, scope="session")
def _isolated_plan_cache(tmp_path_factory):
    import os

    prev = os.environ.get("REPRO_PLAN_CACHE_DIR")
    os.environ["REPRO_PLAN_CACHE_DIR"] = str(
        tmp_path_factory.mktemp("plan_cache")
    )
    yield
    if prev is None:
        os.environ.pop("REPRO_PLAN_CACHE_DIR", None)
    else:
        os.environ["REPRO_PLAN_CACHE_DIR"] = prev
